//! Tables 1–5.

use crate::allocate::Allocation;
use crate::blocks::BlockKind;
use crate::coordinator::dse::DseReport;
use crate::platform::Platform;
use crate::synth::Resource;
use crate::util::error::Result;
use crate::util::format::{fmt_num, Table};

/// Table 1 — related-work resource utilization (static literature data,
/// reproduced verbatim for context; the platforms referenced are all in
/// `platform::Platform::all`).
pub fn table1(french: bool) -> String {
    let mut t = Table::new(vec!["Réf.", "Réseau", "Plateforme", "LUT (%)", "FF (%)", "DSP (%)"])
        .with_title("TABLE 1: Utilisation des ressources pour différentes implémentations de CNN (littérature)");
    let rows: [(&str, &str, &str, f64, f64, f64); 8] = [
        ("[4]", "YOLOv2-Tiny", "KV260", 99.4, 100.0, 100.0),
        ("[7]", "YOLOv3-Tiny (INT8)", "VC709", 39.0, 16.10, 14.28),
        ("[7]", "YOLOv3-Tiny (INT16)", "VC709", 51.73, 20.00, 28.56),
        ("[3]", "RLDA", "ZCU104", 88.2, 33.4, 0.0),
        ("[5]", "LeNet", "Virtex-7", 61.05, 27.02, 2.08),
        ("[5]", "AlexNet", "Virtex-7", 66.35, 31.14, 57.5),
        ("[6]", "VGG-16", "ZCU102", 51.38, 16.64, 20.31),
        ("[6]", "VGG-16", "ZCU111", 73.88, 18.66, 47.94),
    ];
    for (r, net, plat, lut, ff, dsp) in rows {
        t.push_row(vec![
            r.to_string(),
            net.to_string(),
            plat.to_string(),
            fmt_num(lut, 2, french),
            fmt_num(ff, 2, french),
            fmt_num(dsp, 2, french),
        ]);
    }
    t.render()
}

/// Table 2 — block characteristics, regenerated from the implementation
/// (DSP counts and logic classes are asserted against actual synthesis in the
/// integration suite; initiation intervals are our honest microarchitecture
/// numbers — see blocks::mod docs).
pub fn table2() -> String {
    let mut t = Table::new(vec![
        "Bloc",
        "Usage du DSP",
        "Usage de la logique",
        "Lanes",
        "II (cycles/output @ c=8)",
        "Activation",
    ])
    .with_title("TABLE 2: Caractéristiques des blocs de convolution");
    for kind in BlockKind::ALL {
        let dsp = match kind.dsp_count() {
            0 => "Aucun".to_string(),
            1 => "1 DSP".to_string(),
            n => format!("{n} DSPs"),
        };
        let act = kind.block().fused_activation();
        t.push_row(vec![
            kind.name().to_string(),
            dsp,
            kind.logic_usage_class().to_string(),
            kind.convolutions_per_block().to_string(),
            format!(
                "{}",
                kind.initiation_interval(8) / kind.convolutions_per_block()
            ),
            if act == crate::polyapprox::Activation::Identity {
                "—".to_string()
            } else {
                format!("fusée: {act}")
            },
        ]);
    }
    let mut s = t.render();
    s.push_str(
        "NOTE: the paper lists 'une convolution par cycle' for Conv1/Conv2; no 1-DSP or\n~100-LUT datapath sustains 9 MACs/cycle, so we report the honest initiation intervals.\n",
    );
    s
}

/// Table 3 — Pearson correlation quadrants for all four blocks.
pub fn table3(report: &DseReport, french: bool) -> String {
    let mut out = String::new();
    out.push_str("TABLE 3: Corrélation de Pearson\n");
    for block in BlockKind::ALL {
        let quad = report.correlation_quadrant(block);
        let mut header: Vec<String> =
            vec![block.name().into(), "Taille des données".into(), "Taille des coeffs".into()];
        for r in Resource::ALL.iter().take(4) {
            header.push(r.name().to_string());
        }
        let mut t = Table::new(header);
        for (name, vals) in quad {
            let mut row = vec![name];
            for v in vals {
                row.push(fmt_num(v, 3, french));
            }
            t.push_row(row);
        }
        out.push_str(&t.render());
    }
    out
}

/// Table 4 — error metrics of the LLUT models (EQM, EAM, R², EAMP).
pub fn table4(report: &DseReport, french: bool) -> String {
    let mut t = Table::new(vec!["Bloc", "EQM", "EAM", "R²", "EAMP (%)", "modèle"])
        .with_title("TABLE 4: Mesures d'erreur pour les modèles LLUT");
    for block in BlockKind::ALL {
        if let Some(e) = report.registry.get(block, Resource::Llut) {
            t.push_row(vec![
                block.name().to_string(),
                fmt_num(e.metrics.mse, 3, french),
                fmt_num(e.metrics.mae, 3, french),
                fmt_num(e.metrics.r2, 3, french),
                fmt_num(e.metrics.mape, 3, french),
                e.model.kind_name(),
            ]);
        }
    }
    let mut s = t.render();
    // The Conv4 closed form, printed the way the paper states it.
    if let Some(e) = report.registry.get(BlockKind::Conv4, Resource::Llut) {
        if let crate::models::ResourceModel::Poly(p) = &e.model {
            s.push_str(&format!("Conv4 closed form: LLUTs = {}  (R² = {:.3})\n", p.equation(), p.r2));
        }
    }
    s
}

/// Table 5 — predicted resource consumption of block allocations at a
/// utilization cap (default: 8-bit precision, 80 %, ZCU104).
pub fn table5(
    report: &DseReport,
    platform: &Platform,
    data_bits: u32,
    coeff_bits: u32,
    cap: f64,
    french: bool,
) -> Result<String> {
    let rows = report.allocation_study(platform, data_bits, coeff_bits, cap)?;
    let unit = report.unit_costs(data_bits, coeff_bits)?;
    let mut header: Vec<String> = BlockKind::ALL.iter().map(|k| k.name().to_string()).collect();
    header.extend(
        ["LLUT (%)", "FF (%)", "DSP (%)", "CChain (%)", "Total Conv."]
            .into_iter()
            .map(String::from),
    );
    let mut t = Table::new(header).with_title(format!(
        "TABLE 5: Consommation prévue des ressources (%) — {} @ {:.0}% cap, d={data_bits}, c={coeff_bits}",
        platform.name,
        cap * 100.0
    ));
    for (_label, alloc) in &rows {
        let usage = alloc.usage(&unit);
        let u = platform.utilization(&usage);
        let mut row: Vec<String> =
            BlockKind::ALL.iter().map(|k| alloc.count(*k).to_string()).collect();
        row.extend([
            fmt_num(u[0], 1, french),
            fmt_num(u[2], 1, french),
            fmt_num(u[4], 1, french),
            fmt_num(u[3], 1, french),
            alloc.total_convolutions().to_string(),
        ]);
        t.push_row(row);
    }
    Ok(t.render())
}

/// The allocation rows themselves (for tests/benches needing structure).
pub fn table5_rows(
    report: &DseReport,
    platform: &Platform,
    cap: f64,
) -> Result<Vec<(String, Allocation)>> {
    report.allocation_study(platform, 8, 8, cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dse::DseEngine;
    use crate::coordinator::jobs::JobPool;
    use crate::models::SelectOptions;
    use crate::synthdata::SweepOptions;

    fn report() -> DseReport {
        DseEngine {
            sweep: SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() },
            select: SelectOptions::default(),
            pool: JobPool::with_workers(1),
            cache: None,
        }
        .run()
        .unwrap()
    }

    #[test]
    fn table1_contains_literature_rows() {
        let s = table1(true);
        assert!(s.contains("YOLOv2-Tiny"));
        assert!(s.contains("99,4"));
        let s_en = table1(false);
        assert!(s_en.contains("99.40"));
    }

    #[test]
    fn table2_lists_all_blocks() {
        let s = table2();
        for k in BlockKind::ALL {
            assert!(s.contains(k.name()));
        }
        assert!(s.contains("Aucun"));
        assert!(s.contains("NOTE"));
        assert!(s.contains("fusée: sigmoid2"), "{s}");
    }

    #[test]
    fn table3_has_four_quadrants() {
        let rep = report();
        let s = table3(&rep, true);
        for k in BlockKind::ALL {
            assert!(s.contains(k.name()));
        }
        // Conv3's zero data correlation printed with the paper's convention.
        assert!(s.contains("0,000"));
    }

    #[test]
    fn table4_reports_metrics_per_block() {
        let rep = report();
        let s = table4(&rep, false);
        assert!(s.contains("Conv1"));
        assert!(s.contains("EQM"));
        assert!(s.contains("closed form") || s.contains("segmented"));
    }

    #[test]
    fn table5_renders_five_rows() {
        let rep = report();
        let s = table5(&rep, &Platform::zcu104(), 8, 8, 0.8, true).unwrap();
        assert!(s.contains("Total Conv."));
        // header + mix row + one single-type row per registered block
        assert_eq!(
            s.lines().filter(|l| l.starts_with('|')).count(),
            2 + BlockKind::ALL.len()
        );
    }
}
