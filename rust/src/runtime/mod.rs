//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client) following the reference
//! wiring in `/opt/xla-example/load_hlo`: HLO **text** is the interchange
//! format (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's
//! proto path rejects; the text parser reassigns ids). Artifacts are produced
//! once by `make artifacts` (`python/compile/aot.py`); Python never runs on
//! this path.
//!
//! ## Feature gate
//!
//! The `xla` crate is not vendorable in offline environments, so the real
//! runtime compiles only with `--features pjrt` (add `xla = "0.5"` to the
//! manifest's `[dependencies]` where the crate is available). Without the
//! feature this module provides an API-identical **stub** whose entry points
//! return [`Error::Runtime`]. PJRT consumers (tests, benches, `serve`)
//! gate on BOTH [`runtime_available`] and the artifacts directory existing,
//! so the default build degrades gracefully instead of failing to link —
//! even on a machine where `make artifacts` has run.

use std::collections::BTreeMap;
use std::path::PathBuf;

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// True when this build carries the real PJRT runtime (`--features pjrt`);
/// false for the stub, whose entry points only return errors. Artifact-gated
/// tests and benches must check this alongside the artifacts directory.
pub fn runtime_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Metadata sidecar for one artifact (written by `aot.py` as `NAME.meta`,
/// simple `key=value` lines).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactMeta {
    /// Entries as written by the compiler.
    pub fields: BTreeMap<String, String>,
}

impl ArtifactMeta {
    /// Parse `key=value` lines.
    pub fn parse(text: &str) -> ArtifactMeta {
        let mut fields = BTreeMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                fields.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        ArtifactMeta { fields }
    }

    /// Look up a field.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(String::as_str)
    }

    /// Parse a comma-separated dims field, e.g. `input_shape=1,12,12`.
    pub fn dims(&self, key: &str) -> Option<Vec<usize>> {
        self.get(key).map(|v| {
            v.split(',').filter_map(|s| s.trim().parse::<usize>().ok()).collect()
        })
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::ArtifactMeta;
    use crate::util::error::{Error, Result};
    use std::path::Path;

    fn unavailable(what: &str) -> Error {
        Error::Runtime(format!(
            "{what}: convkit was built without the `pjrt` feature (the xla crate is not \
             vendored here); rebuild with `--features pjrt` on a machine that has it"
        ))
    }

    /// A compiled, executable artifact (stub: never constructible — loading
    /// requires the PJRT client).
    pub struct CompiledArtifact {
        /// Artifact name (file stem).
        pub name: String,
        /// Sidecar metadata.
        pub meta: ArtifactMeta,
        _priv: (),
    }

    impl std::fmt::Debug for CompiledArtifact {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("CompiledArtifact").field("name", &self.name).finish()
        }
    }

    impl CompiledArtifact {
        /// Stub: always an error.
        pub fn run_i32(&self, _args: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
            Err(unavailable("run_i32"))
        }

        /// Stub: always an error.
        pub fn run_f32(&self, _args: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            Err(unavailable("run_f32"))
        }
    }

    /// The PJRT runtime (stub).
    #[derive(Debug)]
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        /// Stub: always an error (callers gate on artifacts existing first).
        pub fn cpu() -> Result<Runtime> {
            Err(unavailable("Runtime::cpu"))
        }

        /// Backend platform name.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Stub: always an error.
        pub fn load(&self, path: &Path) -> Result<CompiledArtifact> {
            Err(unavailable(&format!("load {}", path.display())))
        }

        /// Stub: always an error.
        pub fn load_named(&self, dir: &Path, name: &str) -> Result<CompiledArtifact> {
            self.load(&dir.join(format!("{name}.hlo.txt")))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{CompiledArtifact, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::ArtifactMeta;
    use crate::util::error::{Error, Result};
    use std::path::{Path, PathBuf};

    /// A compiled, executable artifact.
    pub struct CompiledArtifact {
        /// Artifact name (file stem).
        pub name: String,
        /// Sidecar metadata.
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    impl std::fmt::Debug for CompiledArtifact {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("CompiledArtifact").field("name", &self.name).finish()
        }
    }

    /// The PJRT runtime: one CPU client, many compiled artifacts.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime").field("platform", &self.platform()).finish()
        }
    }

    impl Runtime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
            Ok(Runtime { client })
        }

        /// Backend platform name ("cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact (plus its `.meta` sidecar if present) and
        /// compile it for this client.
        pub fn load(&self, path: &Path) -> Result<CompiledArtifact> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("artifact")
                .trim_end_matches(".hlo")
                .to_string();
            let meta_path = path.with_extension("").with_extension("meta");
            let meta = if meta_path.exists() {
                ArtifactMeta::parse(&std::fs::read_to_string(&meta_path)?)
            } else {
                // aot.py writes NAME.meta next to NAME.hlo.txt.
                let alt = PathBuf::from(format!(
                    "{}.meta",
                    path.display().to_string().trim_end_matches(".hlo.txt")
                ));
                if alt.exists() {
                    ArtifactMeta::parse(&std::fs::read_to_string(&alt)?)
                } else {
                    ArtifactMeta::default()
                }
            };
            Ok(CompiledArtifact { name, meta, exe })
        }

        /// Load `artifacts/NAME.hlo.txt` from the conventional directory.
        pub fn load_named(&self, dir: &Path, name: &str) -> Result<CompiledArtifact> {
            self.load(&dir.join(format!("{name}.hlo.txt")))
        }
    }

    impl CompiledArtifact {
        /// Execute on i32 tensors: `(data, dims)` per argument, returning the
        /// flattened i32 results of the output tuple.
        pub fn run_i32(&self, args: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
            let mut literals = Vec::with_capacity(args.len());
            for (data, dims) in args {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch {}: {e}", self.name)))?;
            let tuple = result
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("tuple {}: {e}", self.name)))?;
            let mut out = Vec::with_capacity(tuple.len());
            for t in tuple {
                out.push(
                    t.to_vec::<i32>()
                        .map_err(|e| Error::Runtime(format!("to_vec {}: {e}", self.name)))?,
                );
            }
            Ok(out)
        }

        /// Execute on f32 tensors (same contract as [`Self::run_i32`]).
        pub fn run_f32(&self, args: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(args.len());
            for (data, dims) in args {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch {}: {e}", self.name)))?;
            let tuple = result
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("tuple {}: {e}", self.name)))?;
            let mut out = Vec::with_capacity(tuple.len());
            for t in tuple {
                out.push(
                    t.to_vec::<f32>()
                        .map_err(|e| Error::Runtime(format!("to_vec {}: {e}", self.name)))?,
                );
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{CompiledArtifact, Runtime};

/// Locate the artifacts directory: `$CONVKIT_ARTIFACTS`, else `./artifacts`,
/// else the repo-root `artifacts/` relative to the manifest.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CONVKIT_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_key_values_and_dims() {
        let m = ArtifactMeta::parse("name = cnn\ninput_shape = 1,12,12\nnoise\nshift=4\n");
        assert_eq!(m.get("name"), Some("cnn"));
        assert_eq!(m.dims("input_shape"), Some(vec![1, 12, 12]));
        assert_eq!(m.get("shift"), Some("4"));
        assert_eq!(m.get("missing"), None);
    }

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    // PJRT-dependent tests live in rust/tests/integration_runtime.rs, gated on
    // the artifacts' existence, so `cargo test` works before `make artifacts`.
}
