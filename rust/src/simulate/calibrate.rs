//! Contention-slope calibration: fit the engine's `1 + α·x` interference
//! model to measured co-location slowdowns.
//!
//! The virtual-clock engine stretches a batch's service time by
//! `1 + α · x`, where `x` is the co-located utilization share on the same
//! device *excluding the replica itself* (see
//! [`super::engine::DEFAULT_CONTENTION_ALPHA`]). The default slope ships
//! calibrated from a shared-bandwidth microbenchmark
//! (`scripts/calibrate_alpha.py`); fleets running on different hosts should
//! re-fit it against their own silicon and install the result with
//! `SimFleet::set_contention_alpha`:
//!
//! 1. Measure a solo replica's per-pass time `t₁`, then the per-worker time
//!    `t_K` with `K` co-located replicas streaming simultaneously.
//! 2. Estimate one worker's device share `u` = solo bandwidth / peak
//!    aggregate bandwidth (`u = 1` when a single worker already saturates
//!    the device; `u ≈ 1/cores` when the memory system scales out).
//! 3. Each `K`-worker run samples the curve at `x = (K-1)·u` with slowdown
//!    `s = t_K / t₁`; feed the `(x, s)` points with `x ≤ 1` to [`fit_alpha`]
//!    — the simulator packs devices to at most their capped budget, so
//!    oversubscribed samples (`x > 1`) extrapolate interference the model
//!    never evaluates.
//!
//! The estimator here and the one in `scripts/calibrate_alpha.py` are the
//! same formula; the calibration report the shipped default came from is
//! archived at `docs/alpha_calibration.json` and the procedure is documented
//! in `docs/GUIDE.md`.

/// Least-squares fit of `slowdown = 1 + α·x` through the origin:
/// `α = Σ((s−1)·x) / Σ(x²)` over `(x, slowdown)` points. Returns 0.0 when the
/// points carry no signal (empty, or all `x = 0`) — the caller keeps its
/// current slope in that case.
pub fn fit_alpha(points: &[(f64, f64)]) -> f64 {
    let num: f64 = points.iter().map(|&(x, s)| (s - 1.0) * x).sum();
    let den: f64 = points.iter().map(|&(x, _)| x * x).sum();
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Convert raw co-location measurements into fit points for [`fit_alpha`]:
/// `(K, t_K)` per-worker pass times (seconds, including the solo `K = 1`
/// run) plus the estimated per-worker device share `u`, filtered to the
/// simulator's operating regime `x ≤ 1`. Returns an empty vector when no
/// solo baseline is present.
pub fn contention_points(samples: &[(usize, f64)], share_u: f64) -> Vec<(f64, f64)> {
    let Some(&(_, solo)) = samples.iter().find(|&&(k, _)| k == 1) else {
        return Vec::new();
    };
    if solo <= 0.0 {
        return Vec::new();
    }
    samples
        .iter()
        .filter(|&&(k, _)| k > 1)
        .map(|&(k, t)| ((k as f64 - 1.0) * share_u, t / solo))
        .filter(|&(x, _)| x <= 1.0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_is_recovered() {
        // Points on slowdown = 1 + 0.75x fit back to exactly 0.75.
        let pts: Vec<(f64, f64)> =
            [0.25, 0.5, 1.0].iter().map(|&x| (x, 1.0 + 0.75 * x)).collect();
        assert!((fit_alpha(&pts) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn no_signal_fits_zero() {
        assert_eq!(fit_alpha(&[]), 0.0);
        assert_eq!(fit_alpha(&[(0.0, 3.0)]), 0.0);
    }

    #[test]
    fn shipped_default_reproduces_from_its_archived_measurement() {
        // docs/alpha_calibration.json: 1-CPU host, u = 1.0, K=2 slowdown
        // 3.0662 at x = 1.0 (the K=4 x=3.0 point is outside the fit regime).
        let samples = [(1usize, 0.005576321), (2, 0.0170981695), (4, 0.0395663512)];
        let pts = contention_points(&samples, 1.0);
        assert_eq!(pts.len(), 1, "oversubscribed x=3 point must be dropped");
        let alpha = fit_alpha(&pts);
        assert!((alpha - 2.066).abs() < 1e-2, "alpha = {alpha}");
        // ... and the shipped default is that value rounded.
        assert!((super::super::engine::DEFAULT_CONTENTION_ALPHA - alpha).abs() < 0.01);
    }

    #[test]
    fn contention_points_needs_a_solo_baseline() {
        assert!(contention_points(&[(2, 0.02), (4, 0.04)], 0.5).is_empty());
        assert!(contention_points(&[(1, 0.0), (2, 0.02)], 0.5).is_empty());
    }

    #[test]
    fn weighted_fit_prefers_far_points() {
        // Two inconsistent samples: the x-weighted estimator leans toward
        // the larger-share point, where interference actually matters.
        let pts = [(0.1, 1.5), (1.0, 2.0)];
        let alpha = fit_alpha(&pts);
        assert!(alpha > 1.0 && alpha < 1.5, "alpha = {alpha}");
    }
}
