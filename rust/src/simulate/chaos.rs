//! Seeded fault-injection plans driven through the virtual-clock fleet —
//! chaos engineering as a pure function of `(plan, trace, policy)`.
//!
//! A [`ChaosPlan`] schedules faults on the virtual clock: replica deaths,
//! wedged-worker stalls ([`super::engine::SimFleet::wedge_replica`]),
//! whole-device outages and rebinds
//! ([`super::engine::SimFleet::fail_device`] /
//! [`super::engine::SimFleet::rebind_device`]), and correlated burst storms
//! that multiply trace arrivals inside a window. [`run_chaos`] replays a
//! [`Trace`] against a [`super::engine::SimFleet`] with the *production*
//! [`Autoscaler`] in the loop — the same `ScaleTarget` path every capacity
//! run exercises — injecting each fault at its scheduled instant and then
//! watching an independent [`SloTracker`] until every affected network
//! leaves `Overloaded` ([`crate::fleetplan::recovered`]). The first control
//! tick at which that holds stamps the fault's `recovery_ms`.
//!
//! Priority tiers ride along: every arrival draws its
//! [`Priority`] from the plan's seeded [`SplitMix64`] stream
//! (`batch_frac` of arrivals are batch tier), so overload sheds batch work
//! first — [`super::engine::SimFleet::offer_prioritized`] applies the SAME
//! [`crate::coordinator::batch_queue_share`] law the live sharded service
//! enforces. The run's accounting is closed: per network and per tier,
//! `offered == completed + rejected + shed` exactly
//! ([`ChaosReport::conserved`]), a property `rust/tests/property_suite.rs`
//! fuzzes across seeds × fault classes.
//!
//! Every injected fault is journaled as a
//! [`crate::obs::JournalKind::Chaos`] event into the telemetry plane the
//! controllers journal their reactions into (when one is attached via
//! `WhatIfOptions::obs`), so one timeline interleaves cause and response.
//! Determinism contract: same plan + same trace + same policy ⇒
//! [`ChaosReport::to_json`] is byte-identical — CI runs `convkit chaos`
//! twice and diffs the bytes.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::Priority;
use crate::fleetplan::{
    recovered, select_platform_or_spill, Autoscaler, NetworkDemand, ScaleAction, ScaleDecision,
    ScaleTarget, SloPolicy, SloTracker, SpillPlan,
};
use crate::models::ModelRegistry;
use crate::obs::{JournalEvent, JournalKind};
use crate::platform::Platform;
use crate::util::error::Result;
use crate::util::rng::SplitMix64;

use super::clock::SimNs;
use super::engine::{SimFleet, SimNetStats, SimRunOptions, TrajectoryPoint};
use super::whatif::{
    autosize_scenario, json_escape, plan_rows, scalers_for, sim_fleet, WhatIfOptions,
};
use super::workload::{Scenario, Trace};

/// One scheduled fault. All times are virtual milliseconds from run start,
/// matching the trace's clock.
#[derive(Debug, Clone, PartialEq)]
pub enum ChaosFault {
    /// Remove one replica of `network` (highest ordinal, drain-safe) — the
    /// simulator's `scale_down`, i.e. the live `remove_shard` semantics.
    /// Refused (and journaled as refused) when it is the last replica.
    KillReplica {
        /// Injection time (virtual ms).
        at_ms: f64,
        /// Network to shrink.
        network: String,
    },
    /// Stall one replica: admitted work keeps its queue slots but nothing
    /// dispatches until the stall elapses — the wedged-worker failure mode.
    /// `stats()` snapshots stay instant throughout, exactly as live.
    WedgeReplica {
        /// Injection time (virtual ms).
        at_ms: f64,
        /// Network owning the replica.
        network: String,
        /// Replica ordinal within the network (0-based).
        ordinal: usize,
        /// Stall duration (virtual ms).
        stall_ms: f64,
    },
    /// Kill every replica on a device (drain-safe): a power/bitstream loss.
    FailDevice {
        /// Injection time (virtual ms).
        at_ms: f64,
        /// Device (contention group) to take down.
        device: String,
    },
    /// Reprogram a device mid-trace: drain whatever it serves, pay the
    /// reconfiguration outage, then activate fresh replicas of `network`.
    RebindDevice {
        /// Injection time (virtual ms).
        at_ms: f64,
        /// Device to reprogram.
        device: String,
        /// Network whose bitstream the device loads.
        network: String,
        /// Fresh replicas to activate after the outage.
        replicas: usize,
        /// Reconfiguration outage (virtual ms).
        downtime_ms: f64,
    },
    /// Correlated arrival storm: every trace arrival inside
    /// `[at_ms, at_ms + len_ms)` is offered `factor` times instead of once.
    /// Applied when arrivals are built, so the storm is part of the
    /// deterministic workload, not a runtime mutation.
    BurstStorm {
        /// Window start (virtual ms).
        at_ms: f64,
        /// Window length (virtual ms).
        len_ms: f64,
        /// Arrival multiplier (≥ 1; 1 = no-op).
        factor: u32,
    },
}

impl ChaosFault {
    /// Scheduled injection time (virtual ms).
    pub fn at_ms(&self) -> f64 {
        match self {
            ChaosFault::KillReplica { at_ms, .. }
            | ChaosFault::WedgeReplica { at_ms, .. }
            | ChaosFault::FailDevice { at_ms, .. }
            | ChaosFault::RebindDevice { at_ms, .. }
            | ChaosFault::BurstStorm { at_ms, .. } => *at_ms,
        }
    }

    /// Stable snake_case class name used in JSON exports.
    pub fn kind(&self) -> &'static str {
        match self {
            ChaosFault::KillReplica { .. } => "kill_replica",
            ChaosFault::WedgeReplica { .. } => "wedge_replica",
            ChaosFault::FailDevice { .. } => "fail_device",
            ChaosFault::RebindDevice { .. } => "rebind_device",
            ChaosFault::BurstStorm { .. } => "burst_storm",
        }
    }

    /// Short human label for tables and journals.
    pub fn label(&self) -> String {
        match self {
            ChaosFault::KillReplica { network, .. } => format!("kill one `{network}` replica"),
            ChaosFault::WedgeReplica { network, ordinal, stall_ms, .. } => {
                format!("wedge `{network}`#{ordinal} for {stall_ms:.1} ms")
            }
            ChaosFault::FailDevice { device, .. } => format!("fail device `{device}`"),
            ChaosFault::RebindDevice { device, network, replicas, downtime_ms, .. } => format!(
                "rebind `{device}` to {replicas}×`{network}` ({downtime_ms:.1} ms outage)"
            ),
            ChaosFault::BurstStorm { len_ms, factor, .. } => {
                format!("burst storm ×{factor} for {len_ms:.1} ms")
            }
        }
    }
}

/// A deterministic fault-injection plan: the seed that assigns arrival
/// tiers, the batch-tier traffic fraction, and the scheduled faults.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosPlan {
    /// Seed for the tier-assignment stream (and any future chaos draws).
    pub seed: u64,
    /// Fraction of arrivals offered at [`Priority::Batch`] (clamped 0..=1).
    pub batch_frac: f64,
    /// Faults, injected in time order (plan order breaks ties).
    pub faults: Vec<ChaosFault>,
}

impl ChaosPlan {
    /// Plan with no faults — a tiered baseline run.
    pub fn new(seed: u64, batch_frac: f64) -> ChaosPlan {
        ChaosPlan { seed, batch_frac, faults: Vec::new() }
    }

    /// Append a fault (builder style).
    pub fn with_fault(mut self, fault: ChaosFault) -> ChaosPlan {
        self.faults.push(fault);
        self
    }
}

/// Outcome of one injected fault.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultReport {
    /// Stable class name ([`ChaosFault::kind`]).
    pub kind: String,
    /// Human label ([`ChaosFault::label`]).
    pub label: String,
    /// Injection time (virtual ms).
    pub at_ms: f64,
    /// Networks in the blast radius (device faults: everything the device
    /// hosted at injection; storms: every network in the trace).
    pub affected: Vec<String>,
    /// Whether every affected network left `Overloaded` at some control
    /// tick after injection, per the independent watcher
    /// [`SloTracker`].
    pub recovered: bool,
    /// Virtual ms from injection to the first such tick; when the run ends
    /// still unrecovered, the distance to run end (a lower bound).
    pub recovery_ms: f64,
}

/// Full accounting of one chaos run. Pure function of
/// `(fleet, trace, plan, policy, opts)` — byte-identical JSON across runs.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// Tier-assignment seed ([`ChaosPlan::seed`]).
    pub seed: u64,
    /// Batch-tier arrival fraction actually used (clamped).
    pub batch_frac: f64,
    /// Virtual duration of the run (ms).
    pub virtual_ms: f64,
    /// Discrete events processed.
    pub events: u64,
    /// Requests offered (storm amplification included).
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Interactive-tier requests turned away with every replica at cap.
    pub rejected: u64,
    /// Batch-tier requests shed at admission (interactive protection).
    pub shed: u64,
    /// Requests completed.
    pub completed: u64,
    /// `offered` by tier (index = [`Priority::index`]).
    pub offered_tier: [u64; Priority::COUNT],
    /// `rejected` by tier.
    pub rejected_tier: [u64; Priority::COUNT],
    /// `shed` by tier.
    pub shed_tier: [u64; Priority::COUNT],
    /// `completed` by tier.
    pub completed_tier: [u64; Priority::COUNT],
    /// Whether `offered == completed + rejected + shed` held per network
    /// per tier — the conservation invariant (admitted work is never lost).
    pub conserved: bool,
    /// One row per scheduled fault, plan order within a tie, time order
    /// overall.
    pub faults: Vec<FaultReport>,
    /// Per-network totals, name order.
    pub networks: Vec<SimNetStats>,
    /// Scale-up decisions the controllers took while absorbing the plan.
    pub scale_ups: usize,
    /// Scale-down decisions.
    pub scale_downs: usize,
    /// Replica trajectory: initial counts plus every change point (ticks
    /// AND fault injections move counts here, unlike a plain trace run).
    pub trajectory: Vec<TrajectoryPoint>,
    /// Controller decisions, rendered with their virtual timestamps.
    pub decisions: Vec<String>,
}

impl ChaosReport {
    /// Worst per-fault recovery time (ms); 0 when the plan had no faults.
    pub fn worst_recovery_ms(&self) -> f64 {
        self.faults.iter().map(|f| f.recovery_ms).fold(0.0f64, f64::max)
    }

    /// Batch-tier completion rate relative to interactive, capped at 1:
    /// `(batch completed/offered) / (interactive completed/offered)`.
    /// 1.0 when either tier saw no traffic (fairness is vacuous) — and
    /// 1.0 is the ideal: batch completes at the same rate interactive
    /// does. Values below the WFQ weight share indicate starvation.
    pub fn tier_fairness(&self) -> f64 {
        let b = Priority::Batch.index();
        let i = Priority::Interactive.index();
        if self.offered_tier[b] == 0 || self.offered_tier[i] == 0 {
            return 1.0;
        }
        let batch = self.completed_tier[b] as f64 / self.offered_tier[b] as f64;
        let inter = self.completed_tier[i] as f64 / self.offered_tier[i] as f64;
        if inter <= 0.0 {
            return 1.0;
        }
        (batch / inter).min(1.0)
    }
}

impl ChaosReport {
    /// Deterministic JSON under a top-level `"chaos"` key — the
    /// `CHAOS_report.json` CI archives and byte-diffs.
    pub fn to_json(&self) -> String {
        fn tier(v: &[u64; Priority::COUNT]) -> String {
            let inner: Vec<String> = v.iter().map(|x| x.to_string()).collect();
            format!("[{}]", inner.join(", "))
        }
        let mut out = String::from("{\n  \"chaos\": {\n");
        out.push_str(&format!(
            "    \"seed\": {}, \"batch_frac\": {:.3}, \"virtual_ms\": {:.3}, \"events\": {},\n",
            self.seed, self.batch_frac, self.virtual_ms, self.events
        ));
        out.push_str(&format!(
            "    \"offered\": {}, \"admitted\": {}, \"rejected\": {}, \"shed\": {}, \"completed\": {},\n",
            self.offered, self.admitted, self.rejected, self.shed, self.completed
        ));
        out.push_str(&format!(
            "    \"offered_tier\": {}, \"rejected_tier\": {}, \"shed_tier\": {}, \"completed_tier\": {},\n",
            tier(&self.offered_tier),
            tier(&self.rejected_tier),
            tier(&self.shed_tier),
            tier(&self.completed_tier)
        ));
        out.push_str(&format!("    \"conserved\": {},\n", self.conserved));
        out.push_str(&format!(
            "    \"scale_ups\": {}, \"scale_downs\": {}, \"worst_recovery_ms\": {:.3}, \"tier_fairness\": {:.4},\n",
            self.scale_ups,
            self.scale_downs,
            self.worst_recovery_ms(),
            self.tier_fairness()
        ));
        out.push_str("    \"faults\": [\n");
        for (i, f) in self.faults.iter().enumerate() {
            let affected: Vec<String> =
                f.affected.iter().map(|n| format!("\"{}\"", json_escape(n))).collect();
            out.push_str(&format!(
                "      {{\"kind\": \"{}\", \"label\": \"{}\", \"at_ms\": {:.3}, \"affected\": [{}], \"recovered\": {}, \"recovery_ms\": {:.3}}}{}\n",
                f.kind,
                json_escape(&f.label),
                f.at_ms,
                affected.join(", "),
                f.recovered,
                f.recovery_ms,
                if i + 1 == self.faults.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"networks\": [\n");
        for (i, n) in self.networks.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"network\": \"{}\", \"offered\": {}, \"admitted\": {}, \"rejected\": {}, \"shed\": {}, \"completed\": {}, \"offered_tier\": {}, \"rejected_tier\": {}, \"shed_tier\": {}, \"completed_tier\": {}, \"overload_rate\": {:.6}, \"mean_ms\": {:.6}, \"p95_ms\": {:.6}}}{}\n",
                json_escape(&n.network),
                n.offered,
                n.admitted,
                n.rejected,
                n.shed,
                n.completed,
                tier(&n.offered_tier),
                tier(&n.rejected_tier),
                tier(&n.shed_tier),
                tier(&n.completed_tier),
                n.overload_rate,
                n.mean_ms,
                n.p95_ms,
                if i + 1 == self.networks.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"trajectory\": [\n");
        for (i, p) in self.trajectory.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"t_ms\": {:.3}, \"network\": \"{}\", \"replicas\": {}}}{}\n",
                p.t_ms,
                json_escape(&p.network),
                p.replicas,
                if i + 1 == self.trajectory.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"decisions\": [\n");
        for (i, d) in self.decisions.iter().enumerate() {
            out.push_str(&format!(
                "      \"{}\"{}\n",
                json_escape(d),
                if i + 1 == self.decisions.len() { "" } else { "," }
            ));
        }
        out.push_str("    ]\n  }\n}\n");
        out
    }
}

/// One arrival after tier assignment and storm amplification.
struct ChaosArrival {
    at_ns: SimNs,
    net: String,
    priority: Priority,
}

/// A scheduled fault plus its runtime bookkeeping.
struct PendingFault {
    at_ns: SimNs,
    fault: ChaosFault,
    affected: Vec<String>,
    injected: bool,
    recovered_at: Option<SimNs>,
}

/// Distinct (sorted) network names appearing in the trace — a storm's
/// blast radius.
fn trace_networks(trace: &Trace) -> Vec<String> {
    let mut nets = trace.networks.clone();
    nets.sort();
    nets.dedup();
    nets
}

/// Expand the trace into tier-tagged arrivals. The tier of EVERY offered
/// copy is drawn from the plan's seeded stream in arrival order, so the
/// workload is a pure function of `(trace, plan)` — storms amplify
/// arrivals at build time (copies share the timestamp; insertion order
/// keeps the expansion stable).
fn build_arrivals(trace: &Trace, plan: &ChaosPlan) -> Vec<ChaosArrival> {
    let mut rng = SplitMix64::new(plan.seed);
    let frac = plan.batch_frac.clamp(0.0, 1.0);
    let mut out = Vec::with_capacity(trace.events.len());
    for e in &trace.events {
        let mut copies = 1u64;
        for f in &plan.faults {
            if let ChaosFault::BurstStorm { at_ms, len_ms, factor } = f {
                let start = (at_ms.max(0.0) * 1e6) as SimNs;
                let end = start.saturating_add((len_ms.max(0.0) * 1e6) as SimNs);
                if e.at_ns >= start && e.at_ns < end {
                    copies += u64::from((*factor).saturating_sub(1));
                }
            }
        }
        for _ in 0..copies {
            let priority =
                if rng.next_f64() < frac { Priority::Batch } else { Priority::Interactive };
            out.push(ChaosArrival {
                at_ns: e.at_ns,
                net: trace.network_of(e).to_string(),
                priority,
            });
        }
    }
    out
}

/// Apply one fault to the fleet and journal it (when any scaler carries a
/// telemetry plane). Device blast radii are computed HERE, against the
/// fleet as it stands at injection — not at plan time.
fn inject(fleet: &mut SimFleet, scalers: &mut [Autoscaler], pf: &mut PendingFault) -> Result<()> {
    fleet.run_until(pf.at_ns);
    let t_ms = pf.at_ns as f64 / 1e6;
    let (network, device, from, to, reason) = match &pf.fault {
        ChaosFault::KillReplica { network, .. } => {
            let from = fleet.replica_count(network) as u64;
            let outcome = fleet.scale_down(network);
            let to = fleet.replica_count(network) as u64;
            let reason = match outcome {
                Ok(()) => format!("chaos: killed one `{network}` replica"),
                Err(e) => format!("chaos: kill refused ({e})"),
            };
            (network.clone(), None, from, to, reason)
        }
        ChaosFault::WedgeReplica { network, ordinal, stall_ms, .. } => {
            let until = pf.at_ns.saturating_add((stall_ms.max(0.0) * 1e6) as SimNs);
            let hit = fleet.wedge_replica(network, *ordinal, until);
            let n = fleet.replica_count(network) as u64;
            let reason = if hit {
                format!("chaos: wedged `{network}`#{ordinal} for {stall_ms:.1} ms")
            } else {
                format!("chaos: wedge target `{network}`#{ordinal} not found")
            };
            (network.clone(), None, n, n, reason)
        }
        ChaosFault::FailDevice { device, .. } => {
            pf.affected = fleet.networks_on_device(device);
            let lost = fleet.fail_device(device);
            let reason = format!("chaos: device `{device}` lost ({lost} replicas draining out)");
            let first = pf.affected.first().cloned().unwrap_or_default();
            (first, Some(device.clone()), lost as u64, 0, reason)
        }
        ChaosFault::RebindDevice { device, network, replicas, downtime_ms, .. } => {
            let mut affected = fleet.networks_on_device(device);
            if !affected.contains(network) {
                affected.push(network.clone());
                affected.sort();
            }
            let drained = fleet.rebind_device(device, network, *replicas, *downtime_ms)?;
            pf.affected = affected;
            let reason = format!(
                "chaos: rebound `{device}` to {replicas}×`{network}` ({drained} drained, {downtime_ms:.1} ms outage)"
            );
            (network.clone(), Some(device.clone()), drained as u64, *replicas as u64, reason)
        }
        ChaosFault::BurstStorm { factor, len_ms, .. } => {
            // The amplified arrivals were built into the workload; the
            // injection only marks the storm on the journal timeline.
            let reason = format!("chaos: burst storm ×{factor} for {len_ms:.1} ms");
            (String::new(), None, 0, 0, reason)
        }
    };
    pf.injected = true;
    if let Some(obs) = scalers.iter().find_map(|s| s.obs()) {
        obs.record_decision(JournalEvent {
            t_ms,
            kind: JournalKind::Chaos,
            network,
            device,
            from_replicas: from,
            to_replicas: to,
            reason,
            inputs: vec![("at_ms".to_string(), t_ms)],
        });
    }
    Ok(())
}

/// Runtime state threaded through one chaos run: the production scalers,
/// the independent SLO watcher, the sorted fault schedule, the control
/// cadence, and the replica trajectory.
struct Driver<'a> {
    scalers: &'a mut [Autoscaler],
    watcher: SloTracker,
    faults: Vec<PendingFault>,
    next_fault: usize,
    next_tick: SimNs,
    interval: SimNs,
    decisions: Vec<ScaleDecision>,
    trajectory: Vec<TrajectoryPoint>,
    last_counts: BTreeMap<String, usize>,
}

impl Driver<'_> {
    /// Record any replica-count change as a trajectory point. Unlike a
    /// plain trace run, counts here move at fault injections too, not just
    /// at control ticks.
    fn note_counts(&mut self, fleet: &SimFleet) {
        let counts = fleet.replica_counts();
        if counts != self.last_counts {
            let t_ms = fleet.now_ms();
            for (net, n) in &counts {
                if self.last_counts.get(net) != Some(n) {
                    self.trajectory.push(TrajectoryPoint {
                        t_ms,
                        network: net.clone(),
                        replicas: *n,
                    });
                }
            }
            self.last_counts = counts;
        }
    }

    /// Inject the next scheduled fault.
    fn inject_next(&mut self, fleet: &mut SimFleet) -> Result<()> {
        inject(fleet, self.scalers, &mut self.faults[self.next_fault])?;
        self.next_fault += 1;
        self.note_counts(fleet);
        Ok(())
    }

    /// One control tick: every scaler steps the fleet, then the
    /// independent watcher judges SLO state and stamps any
    /// injected-but-unrecovered fault whose whole blast radius has left
    /// `Overloaded`.
    fn tick(&mut self, fleet: &mut SimFleet, at: SimNs) -> Result<()> {
        fleet.note_tick();
        for sc in self.scalers.iter_mut() {
            self.decisions.extend(sc.step_target(fleet)?);
        }
        let rows = self.watcher.observe(&fleet.stats());
        for pf in self.faults.iter_mut() {
            if pf.injected && pf.recovered_at.is_none() {
                let affected: Vec<&str> = pf.affected.iter().map(|s| s.as_str()).collect();
                if recovered(&rows, &affected) {
                    pf.recovered_at = Some(at);
                }
            }
        }
        self.note_counts(fleet);
        Ok(())
    }

    /// Advance the run to `t`, firing every due fault and control tick in
    /// time order on the way (a fault scheduled at a tick instant injects
    /// BEFORE the tick, so the controller sees the damage on the same
    /// cadence it would live).
    fn advance(&mut self, fleet: &mut SimFleet, t: SimNs) -> Result<()> {
        loop {
            let fault_at =
                self.faults.get(self.next_fault).map(|f| f.at_ns).filter(|&a| a <= t);
            let tick_at = if self.next_tick <= t { Some(self.next_tick) } else { None };
            match (fault_at, tick_at) {
                (Some(fa), ta) if ta.is_none_or(|ta| fa <= ta) => self.inject_next(fleet)?,
                (_, Some(ta)) => {
                    fleet.run_until(ta);
                    self.tick(fleet, ta)?;
                    self.next_tick += self.interval;
                }
                _ => return Ok(()),
            }
        }
    }
}

/// Replay `trace` against `fleet` under `plan`, with the production
/// controllers in the loop and an independent watcher tracking
/// recovery-to-SLO per fault. See the module docs for the full contract;
/// `policy` parameterizes the watcher (it should match the scalers' policy
/// so "recovered" means what the controller means by healthy).
pub fn run_chaos(
    fleet: &mut SimFleet,
    trace: &Trace,
    scalers: &mut [Autoscaler],
    policy: &SloPolicy,
    plan: &ChaosPlan,
    opts: &SimRunOptions,
) -> Result<ChaosReport> {
    let interval = ((opts.control_interval_ms.max(1e-3)) * 1e6) as SimNs;
    let mut faults: Vec<PendingFault> = plan
        .faults
        .iter()
        .map(|f| PendingFault {
            at_ns: (f.at_ms().max(0.0) * 1e6) as SimNs,
            affected: match f {
                ChaosFault::KillReplica { network, .. }
                | ChaosFault::WedgeReplica { network, .. } => vec![network.clone()],
                ChaosFault::BurstStorm { .. } => trace_networks(trace),
                // Device blast radii are computed at injection.
                ChaosFault::FailDevice { .. } | ChaosFault::RebindDevice { .. } => Vec::new(),
            },
            fault: f.clone(),
            injected: false,
            recovered_at: None,
        })
        .collect();
    // Stable sort: same-instant faults inject in plan order.
    faults.sort_by_key(|f| f.at_ns);
    let arrivals = build_arrivals(trace, plan);
    let mut drv = Driver {
        scalers,
        watcher: SloTracker::new(policy.clone()),
        faults,
        next_fault: 0,
        next_tick: fleet.now_ns() + interval,
        interval,
        decisions: Vec::new(),
        trajectory: Vec::new(),
        last_counts: fleet.replica_counts(),
    };
    let t0_ms = fleet.now_ms();
    for (net, n) in &drv.last_counts {
        drv.trajectory.push(TrajectoryPoint {
            t_ms: t0_ms,
            network: net.clone(),
            replicas: *n,
        });
    }

    for a in &arrivals {
        drv.advance(fleet, a.at_ns)?;
        fleet.run_until(a.at_ns);
        fleet.offer_prioritized(&a.net, a.at_ns, a.priority)?;
    }
    // Drain: interleave remaining completions, faults, and the control
    // cadence until the heap and the fault schedule are both exhausted
    // (trailing faults — e.g. a rebind whose activations land after the
    // last arrival — still inject and still get recovery tracking).
    loop {
        let next_fault_at = drv.faults.get(drv.next_fault).map(|f| f.at_ns);
        let target = match (fleet.next_completion_at(), next_fault_at) {
            (None, None) => break,
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) => a,
            (None, Some(b)) => b,
        };
        drv.advance(fleet, target)?;
        fleet.run_until(target);
    }
    // Cooldown ticks: give idle hysteresis its scale-down tail and give
    // late faults their recovery verdicts.
    for _ in 0..opts.cooldown_ticks {
        let at = drv.next_tick;
        fleet.run_until(at);
        drv.tick(fleet, at)?;
        drv.next_tick += interval;
    }

    let end_ns = fleet.now_ns();
    let networks = fleet.network_stats();
    let mut offered_tier = [0u64; Priority::COUNT];
    let mut rejected_tier = [0u64; Priority::COUNT];
    let mut shed_tier = [0u64; Priority::COUNT];
    let mut completed_tier = [0u64; Priority::COUNT];
    let mut conserved = true;
    for n in &networks {
        for i in 0..Priority::COUNT {
            offered_tier[i] += n.offered_tier[i];
            rejected_tier[i] += n.rejected_tier[i];
            shed_tier[i] += n.shed_tier[i];
            completed_tier[i] += n.completed_tier[i];
            if n.offered_tier[i] != n.completed_tier[i] + n.rejected_tier[i] + n.shed_tier[i] {
                conserved = false;
            }
        }
    }
    let fault_reports: Vec<FaultReport> = drv
        .faults
        .iter()
        .map(|pf| FaultReport {
            kind: pf.fault.kind().to_string(),
            label: pf.fault.label(),
            at_ms: pf.at_ns as f64 / 1e6,
            affected: pf.affected.clone(),
            recovered: pf.recovered_at.is_some(),
            recovery_ms: (pf.recovered_at.unwrap_or(end_ns).saturating_sub(pf.at_ns)) as f64
                / 1e6,
        })
        .collect();
    Ok(ChaosReport {
        seed: plan.seed,
        batch_frac: plan.batch_frac.clamp(0.0, 1.0),
        virtual_ms: fleet.now_ms(),
        events: fleet.events_processed(),
        offered: networks.iter().map(|n| n.offered).sum(),
        admitted: networks.iter().map(|n| n.admitted).sum(),
        rejected: networks.iter().map(|n| n.rejected).sum(),
        shed: networks.iter().map(|n| n.shed).sum(),
        completed: networks.iter().map(|n| n.completed).sum(),
        offered_tier,
        rejected_tier,
        shed_tier,
        completed_tier,
        conserved,
        faults: fault_reports,
        networks,
        scale_ups: drv.decisions.iter().filter(|d| d.action == ScaleAction::Up).count(),
        scale_downs: drv.decisions.iter().filter(|d| d.action == ScaleAction::Down).count(),
        trajectory: drv.trajectory,
        decisions: drv
            .decisions
            .iter()
            .map(|d| format!("t=+{:.3}ms {}", d.at_ms, d))
            .collect(),
    })
}

/// Plan-level entry point: build the fleet from a [`SpillPlan`] at its
/// replica floors, arm the production controllers (and the telemetry plane,
/// when `opts.obs` carries one), and run the chaos plan — the same wiring
/// `whatif::explore`'s controlled run uses.
pub fn run_planned_chaos(
    spill: &SpillPlan,
    trace: &Trace,
    policy: &SloPolicy,
    opts: &WhatIfOptions,
    plan: &ChaosPlan,
) -> Result<ChaosReport> {
    let rows = plan_rows(spill);
    let mut fleet = sim_fleet(&rows, opts, |row| row.min_replicas)?;
    let mut scalers = scalers_for(&rows, None, opts, policy);
    if let Some(obs) = &opts.obs {
        fleet.set_telemetry(Arc::clone(obs));
        scalers = scalers.into_iter().map(|s| s.with_obs(Arc::clone(obs))).collect();
    }
    run_chaos(
        &mut fleet,
        trace,
        &mut scalers,
        policy,
        plan,
        &SimRunOptions {
            control_interval_ms: opts.control_interval_ms,
            cooldown_ticks: opts.cooldown_ticks,
        },
    )
}

/// CLI-facing entry point (`convkit chaos`): select a platform for
/// `demands` (with the two-device spill fallback), auto-size `scenario`
/// against the planned replica floors, let `plan_fn` build the fault
/// schedule from what was actually planned — the spill split names the
/// device a `FailDevice` can target, the sized scenario's `duration_ms`
/// anchors fault times as fractions of the run — and drive it all through
/// [`run_planned_chaos`]. Pure function of its inputs, like
/// `whatif::explore`.
pub fn explore_chaos<F>(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    platforms: &[Platform],
    scenario: &Scenario,
    opts: &WhatIfOptions,
    plan_fn: F,
) -> Result<ChaosReport>
where
    F: FnOnce(&SpillPlan, &Scenario) -> ChaosPlan,
{
    let spill = select_platform_or_spill(demands, registry, platforms, opts.cap)?;
    let sc = autosize_scenario(scenario, demands, &spill, opts)?;
    let trace = sc.arrivals();
    let plan = plan_fn(&spill, &sc);
    run_planned_chaos(&spill, &trace, &opts.policy, opts, &plan)
}

#[cfg(test)]
mod tests {
    use super::super::engine::SimServiceModel;
    use super::super::workload::{Scenario, ScenarioShape};
    use super::*;

    fn fleet() -> SimFleet {
        SimFleet::new(&[
            SimServiceModel::new("a", 0.5, 8, 2).on_platform("dev0", 0.2),
            SimServiceModel::new("b", 0.5, 8, 2).on_platform("dev1", 0.2),
        ])
        .unwrap()
    }

    fn trace() -> Trace {
        Scenario::new(
            ScenarioShape::Steady,
            vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
            200.0,
            100.0,
            42,
        )
        .arrivals()
    }

    fn full_plan() -> ChaosPlan {
        ChaosPlan::new(7, 0.10)
            .with_fault(ChaosFault::WedgeReplica {
                at_ms: 10.0,
                network: "a".to_string(),
                ordinal: 0,
                stall_ms: 15.0,
            })
            .with_fault(ChaosFault::KillReplica { at_ms: 25.0, network: "b".to_string() })
            .with_fault(ChaosFault::BurstStorm { at_ms: 40.0, len_ms: 20.0, factor: 3 })
            .with_fault(ChaosFault::FailDevice { at_ms: 60.0, device: "dev1".to_string() })
            .with_fault(ChaosFault::RebindDevice {
                at_ms: 75.0,
                device: "dev1".to_string(),
                network: "b".to_string(),
                replicas: 2,
                downtime_ms: 5.0,
            })
    }

    #[test]
    fn storm_amplifies_only_its_window_and_tiers_are_seeded() {
        let tr = trace();
        let base = build_arrivals(&tr, &ChaosPlan::new(7, 0.10));
        let plan = ChaosPlan::new(7, 0.10).with_fault(ChaosFault::BurstStorm {
            at_ms: 40.0,
            len_ms: 20.0,
            factor: 3,
        });
        let stormy = build_arrivals(&tr, &plan);
        let in_window = |a: &ChaosArrival| a.at_ns >= 40_000_000 && a.at_ns < 60_000_000;
        let base_in = base.iter().filter(|a| in_window(a)).count();
        let storm_in = stormy.iter().filter(|a| in_window(a)).count();
        assert_eq!(storm_in, base_in * 3, "×3 inside the window");
        assert_eq!(
            stormy.len() - storm_in,
            base.len() - base_in,
            "arrivals outside the window are untouched"
        );
        // Monotone timestamps survive amplification (copies share an instant).
        assert!(stormy.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        // Tier assignment is a pure function of the seed.
        let again = build_arrivals(&tr, &plan);
        assert!(stormy
            .iter()
            .zip(again.iter())
            .all(|(x, y)| x.priority == y.priority && x.at_ns == y.at_ns && x.net == y.net));
        assert!(stormy.iter().any(|a| a.priority == Priority::Batch));
        assert!(stormy.iter().any(|a| a.priority == Priority::Interactive));
    }

    #[test]
    fn chaos_run_is_byte_deterministic_and_conserves_every_tier() {
        let tr = trace();
        let opts = SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 4 };
        let policy = SloPolicy::default();
        let run = || {
            let mut f = fleet();
            run_chaos(&mut f, &tr, &mut [], &policy, &full_plan(), &opts).unwrap()
        };
        let one = run();
        let two = run();
        assert_eq!(one.to_json(), two.to_json(), "same plan ⇒ same bytes");
        assert!(one.conserved, "offered == completed + rejected + shed per tier");
        assert_eq!(one.faults.len(), 5);
        assert_eq!(one.offered, one.completed + one.rejected + one.shed);
        assert!(one.offered_tier[Priority::Batch.index()] > 0, "batch traffic present");
        // The storm tripled a 20 ms window, so offered exceeds the trace.
        assert!(one.offered > tr.len() as u64);
        for f in &one.faults {
            assert!(!f.affected.is_empty() || f.kind == "burst_storm");
        }
        // Faults land in time order regardless of plan order.
        assert!(one.faults.windows(2).all(|w| w[0].at_ms <= w[1].at_ms));
    }

    #[test]
    fn healthy_fleet_recovers_at_the_first_tick_after_a_wedge() {
        let tr = trace();
        let plan = ChaosPlan::new(1, 0.0).with_fault(ChaosFault::WedgeReplica {
            at_ms: 10.0,
            network: "a".to_string(),
            ordinal: 0,
            stall_ms: 5.0,
        });
        let mut f = fleet();
        let opts = SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 4 };
        let report =
            run_chaos(&mut f, &tr, &mut [], &SloPolicy::default(), &plan, &opts).unwrap();
        let fr = &report.faults[0];
        assert_eq!(fr.kind, "wedge_replica");
        assert_eq!(fr.affected, vec!["a".to_string()]);
        assert!(fr.recovered, "lightly-loaded fleet is never Overloaded");
        assert!(
            fr.recovery_ms <= opts.control_interval_ms + 1e-9,
            "stamped at the first tick after injection, got {} ms",
            fr.recovery_ms
        );
        assert!(report.conserved);
    }

    #[test]
    fn device_fault_records_blast_radius_at_injection() {
        let tr = trace();
        let plan = ChaosPlan::new(3, 0.0)
            .with_fault(ChaosFault::FailDevice { at_ms: 30.0, device: "dev1".to_string() });
        let mut f = fleet();
        let report = run_chaos(
            &mut f,
            &tr,
            &mut [],
            &SloPolicy::default(),
            &plan,
            &SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 2 },
        )
        .unwrap();
        assert_eq!(report.faults[0].affected, vec!["b".to_string()]);
        assert!(report.conserved, "drained replicas still complete admitted work");
        // `b` lost every replica at 30 ms; later offers are rejected, not lost.
        let b = report.networks.iter().find(|n| n.network == "b").unwrap();
        assert!(b.rejected > 0, "offers to a dead network are rejected");
        assert_eq!(b.offered, b.completed + b.rejected + b.shed);
    }

    #[test]
    fn report_json_shape_is_stable() {
        let tr = trace();
        let mut f = fleet();
        let report = run_chaos(
            &mut f,
            &tr,
            &mut [],
            &SloPolicy::default(),
            &ChaosPlan::new(5, 0.25),
            &SimRunOptions::default(),
        )
        .unwrap();
        let json = report.to_json();
        assert!(json.starts_with("{\n  \"chaos\": {\n"));
        for key in [
            "\"seed\": 5",
            "\"batch_frac\": 0.250",
            "\"offered_tier\": [",
            "\"conserved\": true",
            "\"faults\": [",
            "\"networks\": [",
            "\"decisions\": [",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }
}
