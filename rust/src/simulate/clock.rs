//! Virtual time and the deterministic event heap.
//!
//! Simulated time is a `u64` nanosecond counter ([`SimNs`]) that only ever
//! moves forward by explicit [`VirtualClock::advance_to`] calls — nothing in
//! the simulator sleeps, so a million virtual seconds cost exactly as much
//! wall time as the events scheduled inside them. The [`EventHeap`] is a
//! min-heap keyed by `(time, insertion sequence)`: two events scheduled for
//! the same instant pop in insertion order, which makes every simulation a
//! pure function of its inputs — no `HashMap` iteration order, no thread
//! scheduling, no wall clock anywhere.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in nanoseconds since the start of the simulation.
pub type SimNs = u64;

/// A forward-only virtual clock.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: SimNs,
}

impl VirtualClock {
    /// A clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0 }
    }

    /// Current virtual time (ns).
    pub fn now(&self) -> SimNs {
        self.now
    }

    /// Current virtual time in milliseconds.
    pub fn now_ms(&self) -> f64 {
        self.now as f64 / 1e6
    }

    /// Advance to `t` (a no-op when `t` is in the past — time never rewinds).
    pub fn advance_to(&mut self, t: SimNs) {
        self.now = self.now.max(t);
    }
}

/// One scheduled entry: ordering key is `(at, seq)` only, so the payload
/// type needs no `Ord`.
struct Entry<E> {
    at: SimNs,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Deterministic future-event list: min-heap by time, FIFO within a tick.
pub struct EventHeap<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
}

impl<E> Default for EventHeap<E> {
    fn default() -> Self {
        EventHeap::new()
    }
}

impl<E> EventHeap<E> {
    /// An empty heap.
    pub fn new() -> EventHeap<E> {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at virtual time `at`.
    pub fn push(&mut self, at: SimNs, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { at, seq, event }));
    }

    /// Pop the earliest event (ties in insertion order).
    pub fn pop(&mut self) -> Option<(SimNs, E)> {
        self.heap.pop().map(|Reverse(e)| (e.at, e.event))
    }

    /// Time of the next event, if any.
    pub fn peek_at(&self) -> Option<SimNs> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// Scheduled events still pending.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_never_rewinds() {
        let mut c = VirtualClock::new();
        c.advance_to(100);
        c.advance_to(50);
        assert_eq!(c.now(), 100);
        c.advance_to(2_500_000);
        assert!((c.now_ms() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut h = EventHeap::new();
        h.push(30, "c");
        h.push(10, "a");
        h.push(20, "b");
        assert_eq!(h.len(), 3);
        assert_eq!(h.peek_at(), Some(10));
        assert_eq!(h.pop(), Some((10, "a")));
        assert_eq!(h.pop(), Some((20, "b")));
        assert_eq!(h.pop(), Some((30, "c")));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn same_tick_events_pop_in_insertion_order() {
        let mut h = EventHeap::new();
        for i in 0..32u32 {
            h.push(7, i);
        }
        for i in 0..32u32 {
            assert_eq!(h.pop(), Some((7, i)), "FIFO within a tick");
        }
    }

    #[test]
    fn interleaved_pushes_and_pops_stay_ordered() {
        let mut h = EventHeap::new();
        h.push(5, 'x');
        h.push(1, 'y');
        assert_eq!(h.pop(), Some((1, 'y')));
        h.push(3, 'z');
        h.push(5, 'w');
        assert_eq!(h.pop(), Some((3, 'z')));
        // Both at t=5: 'x' was inserted before 'w'.
        assert_eq!(h.pop(), Some((5, 'x')));
        assert_eq!(h.pop(), Some((5, 'w')));
    }
}
