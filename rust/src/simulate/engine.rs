//! The discrete-event serving engine: bounded-admission queues served at
//! model-predicted rates, on virtual time.
//!
//! A [`SimFleet`] is the simulator's stand-in for a live
//! [`crate::coordinator::ShardedService`]: per-replica FIFO queues with the
//! same bounded-admission semantics (`queue_cap` slots per replica, load-
//! ordered fallback across a network's replicas via the *same*
//! [`Router`] policy object the live fleet uses, one rejection charged to
//! the preferred replica only when EVERY replica is at cap), but with no
//! worker threads and no executors — each replica "serves" requests by
//! scheduling virtual service events, where the service rate comes from the
//! fitted models (`fleetplan::NetworkPlan::predicted_ms`, i.e.
//! [`crate::extend::latency::deployment_latency`] over the plan's block
//! mix). A million requests simulate in well under a second of wall time.
//!
//! ## Batch coalescing
//!
//! Service runs the live worker's coalescing loop — literally the same
//! policy object. Each replica carries a
//! [`CoalescePolicy`](crate::coordinator::CoalescePolicy) (built by
//! [`SimServiceModel::policy`]): a request admitted to an *idle* replica
//! opens the policy's idle window; each further absorbed arrival *extends*
//! the deadline to `window_ns(queued)` past the window's opening (growing
//! one pipeline-fill per request toward the model optimum, capped at the
//! batch runtime); the batch dispatches at the deadline — or immediately
//! once `max_batch` fills — and is priced by the policy's
//! `fill_ns + b × (service_ns − fill_ns)` curve (see
//! [`crate::extend::latency::LatencyEstimate::ms_batch`]). When a batch
//! completes over a backlog, the backlog is absorbed at once and owed
//! `window_ns(backlog)` from that instant — exactly the live
//! `collect_batch`, which drains the channel and only then opens a
//! deadline. The parity test below pins the engine to
//! [`crate::coordinator::coalesce::schedule`], the policy's pure reference
//! interpreter, on a deterministic arrival trace.
//!
//! ## Device contention
//!
//! Replicas co-located on one platform (tagged via
//! [`SimServiceModel::platform`]) share the device: each replica carries the
//! share of the capped budget its block mix occupies
//! ([`SimServiceModel::util_frac`], from `NetworkPlan::util_frac` — the
//! same per-column capacity math `plan_fleet` packs with), and a batch's
//! service time is stretched by
//! `1 + contention_alpha × (co-located share excluding self)`. A lone
//! replica runs at the model-predicted rate; a packed device degrades
//! monotonically in the co-located share — so scale-ups show the
//! diminishing returns a real shared-device fleet shows.
//!
//! The engine implements [`ScaleTarget`], so the *identical*
//! `fleetplan::Autoscaler` control loop that reconfigures production fleets
//! drives the simulation: `scale_up` adds a virtual replica, `scale_down`
//! unroutes-then-drains one (in-flight virtual requests still complete),
//! and `observe` synthesizes the same [`ShardedStats`] rows the live stats
//! plane produces — so SLO windows, hysteresis and budget checks behave
//! identically in rehearsal and in production.
//!
//! ## Priority tiers & fault injection
//!
//! Requests carry a [`Priority`] tier, mirrored from the live coordinator:
//! each replica keeps per-tier FIFO queues drained by the SAME
//! deficit-round-robin [`WfqState`] the live worker's carry runs, and batch
//! admission is capped at [`batch_queue_share`] of the replica cap — batch
//! work past its share is turned away as [`Admission::Shed`] (accounted
//! separately from `Rejected`, which remains the fleet-too-small overload
//! signal). [`SimFleet::offer`] defaults to interactive, so single-tier
//! runs are byte-identical to the pre-tier engine. Fault injection rides
//! the same virtual clock: [`SimFleet::fail_device`] /
//! [`SimFleet::rebind_device`] model outages, and
//! [`SimFleet::wedge_replica`] models a wedged worker — new dispatches on
//! the stalled replica defer until the wake time while `stats()` stays an
//! instant memory read, exactly the live stale-stats behavior
//! (`simulate::chaos` schedules these into seeded plans).

use super::clock::{EventHeap, SimNs, VirtualClock};
use super::workload::Trace;
use crate::coordinator::service::ServiceStats;
use crate::coordinator::shard::aggregate;
use crate::coordinator::{
    batch_queue_share, CoalescePolicy, Priority, Router, ShardSpec, ShardStats, ShardedStats,
    WfqState,
};
use crate::fleetplan::{Autoscaler, ScaleDecision, ScaleTarget};
use crate::obs::trace::{pack, UNTRACED};
use crate::obs::{ModelExpectation, Sink, SpanEvent, SpanKind, SpanScope, Stage, Telemetry};
use crate::util::error::{Error, Result};
use crate::util::stats::window_mean_p95;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Per-replica rolling latency window (mirrors the live service's bounded
/// ring: stats reflect *recent* completions, not lifetime history).
pub const SIM_LATENCY_WINDOW: usize = 1024;

/// Default co-located-share slowdown slope (see the module docs): a device
/// packed to 100% of its capped budget serves each batch `1 + α` times
/// slower than an uncontended replica would.
///
/// Calibrated, not guessed: fitted by least squares from the
/// shared-bandwidth microbenchmark in `scripts/calibrate_alpha.py` (memory-
/// streaming workers co-located on one host; measured slowdown vs
/// co-location share), run on the CI reference container — a single-core
/// host whose solo worker saturates the device, so co-location is full
/// serialization plus cache interference. The raw report is archived at
/// `docs/alpha_calibration.json` and the procedure documented in
/// `docs/GUIDE.md`; fleets on beefier hosts should re-fit with
/// `simulate::calibrate::fit_alpha` and
/// [`SimFleet::set_contention_alpha`].
pub const DEFAULT_CONTENTION_ALPHA: f64 = 2.07;

/// One network's service model inside the simulator.
///
/// ```
/// use convkit::simulate::SimServiceModel;
/// // 0.5 ms per inference, queue cap 8, 2 replicas; coalesce up to 4
/// // requests per batch with a 0.1 ms amortizable pipeline fill.
/// let m = SimServiceModel::new("lenet_q8", 0.5, 8, 2)
///     .with_batching(4, 0.1)
///     .on_platform("ZCU104", 0.12);
/// assert_eq!(m.max_batch, 4);
/// assert_eq!(m.service_ns, 500_000);
/// assert_eq!(m.fill_ns, 100_000);
/// ```
#[derive(Debug, Clone)]
pub struct SimServiceModel {
    /// Network name (routing key).
    pub network: String,
    /// Virtual service time per request (ns) — from the fitted models.
    pub service_ns: u64,
    /// Amortizable pipeline-fill component of `service_ns` (ns): a
    /// coalesced batch of `b` requests takes
    /// `fill_ns + b × (service_ns − fill_ns)` of virtual time
    /// (`NetworkPlan::fill_ms`; 0 = no batching benefit).
    pub fill_ns: u64,
    /// Requests drained per service event (1 = the PR 4
    /// one-request-one-service-time model; the live default is the
    /// `ShardSpec` batch size).
    pub max_batch: usize,
    /// Coalescing window opened when a request lands on an idle replica
    /// (ns; 0 = dispatch immediately, batching only under backlog — see
    /// [`crate::coordinator::service::BATCH_WINDOW`] for the live value).
    pub window_ns: u64,
    /// Per-replica bounded-admission cap.
    pub queue_cap: usize,
    /// Replicas to start with.
    pub replicas: usize,
    /// Hosting device: replicas sharing a platform name contend
    /// (`None` = uncontended).
    pub platform: Option<String>,
    /// Share of the device's capped budget one replica occupies
    /// (`NetworkPlan::util_frac`; only meaningful with `platform`).
    pub util_frac: f64,
}

impl SimServiceModel {
    /// Model from a predicted per-inference latency in milliseconds
    /// (clamped to ≥ 1 ns so a zero prediction cannot wedge the heap).
    /// Batching and contention default OFF: `max_batch` 1, no fill, no
    /// window, no platform.
    pub fn new(
        network: &str,
        service_ms: f64,
        queue_cap: usize,
        replicas: usize,
    ) -> SimServiceModel {
        SimServiceModel {
            network: network.to_string(),
            service_ns: (service_ms * 1e6).max(1.0) as u64,
            fill_ns: 0,
            max_batch: 1,
            window_ns: 0,
            queue_cap: queue_cap.max(1),
            replicas,
            platform: None,
            util_frac: 0.0,
        }
    }

    /// Enable batch coalescing: up to `max_batch` requests per service
    /// event, amortizing `fill_ms` of the service time across the batch.
    pub fn with_batching(mut self, max_batch: usize, fill_ms: f64) -> SimServiceModel {
        self.max_batch = max_batch.max(1);
        self.fill_ns = ((fill_ms * 1e6).max(0.0) as u64).min(self.service_ns.saturating_sub(1));
        self
    }

    /// Set the idle-replica coalescing window (ms of virtual time).
    pub fn with_window_ms(mut self, window_ms: f64) -> SimServiceModel {
        self.window_ns = (window_ms * 1e6).max(0.0) as u64;
        self
    }

    /// Co-locate this network's replicas on `platform`, each occupying
    /// `util_frac` of the device's capped budget.
    pub fn on_platform(mut self, platform: &str, util_frac: f64) -> SimServiceModel {
        self.platform = Some(platform.to_string());
        self.util_frac = util_frac.clamp(0.0, 1.0);
        self
    }

    /// The model's fields as the [`CoalescePolicy`] the live worker would
    /// run with — the shared waiting/pricing law every simulated replica of
    /// this network carries.
    pub fn policy(&self) -> CoalescePolicy {
        CoalescePolicy {
            idle_window_ns: self.window_ns,
            service_ns: self.service_ns,
            fill_ns: self.fill_ns.min(self.service_ns.saturating_sub(1)),
            max_batch: self.max_batch.max(1),
        }
    }
}

/// One virtual replica: a bounded FIFO drained in model-predicted batches.
struct SimReplica {
    id: u64,
    net: u32,
    replica: usize,
    queue_cap: usize,
    /// The SAME waiting/pricing law the live worker runs
    /// ([`crate::coordinator::CoalescePolicy`]): window growth, dispatch
    /// deadline and batch cost all come from here.
    policy: CoalescePolicy,
    device: Option<u32>,
    util_frac: f64,
    /// Shard-identity recording scope, built when the fleet is observed
    /// through [`SimFleet::set_telemetry`]: spans land in the SAME
    /// per-`(network, replica)` rings the live coordinator fills, so ring
    /// attribution and [`crate::obs::drift::DriftMonitor::ingest`] work
    /// identically on both planes.
    scope: Option<SpanScope>,
    /// `(arrival time, trace id)` of admitted requests waiting for a
    /// batch, one FIFO per [`Priority`] tier
    /// ([`crate::obs::trace::UNTRACED`] when the fleet is unobserved).
    queues: [VecDeque<(SimNs, u32)>; Priority::COUNT],
    /// Deficit-round-robin state draining `queues` — the SAME weighted
    /// fair queueing law the live worker's carry runs.
    wfq: WfqState,
    /// Virtual time a wedged-worker stall clears (0 = healthy): while
    /// `now < wedged_until` NEW dispatches defer to the wake time, but the
    /// in-flight batch completes and `stats()` stays instant.
    wedged_until: SimNs,
    /// `(arrival time, trace id, tier)` of the batch in service
    /// (empty = idle).
    in_flight: Vec<(SimNs, u32, Priority)>,
    /// Virtual time the open coalescing window started (deadlines extend
    /// from here as the backlog grows, never from "now").
    window_opened_at: SimNs,
    /// Deadline of the scheduled `Dispatch` event, if a window is open.
    /// Superseded deadlines stay in the heap; their events are recognized
    /// as stale (`at != dispatch_at`) and ignored.
    dispatch_at: Option<SimNs>,
    /// Virtual time the in-flight batch started service (telemetry's exec
    /// stage measures completion − dispatch, as the live worker does).
    dispatched_at: SimNs,
    served: u64,
    batches: u64,
    rejected: u64,
    draining: bool,
    started_at: SimNs,
    lat_win_ns: Vec<u64>,
    lat_next: usize,
}

impl SimReplica {
    /// Admitted-but-incomplete requests (queued + in service) — the live
    /// shard's slot accounting, where a slot frees at *completion*.
    fn outstanding(&self) -> usize {
        self.queued() + self.in_flight.len()
    }

    /// Requests waiting for a batch, across both tiers.
    fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    fn record_latency(&mut self, ns: u64) {
        if self.lat_win_ns.len() < SIM_LATENCY_WINDOW {
            self.lat_win_ns.push(ns);
        } else {
            self.lat_win_ns[self.lat_next] = ns;
        }
        self.lat_next = (self.lat_next + 1) % SIM_LATENCY_WINDOW;
    }
}

/// All-time per-network accounting for the final capacity report, kept
/// per [`Priority`] tier (index = `Priority::index()`); network totals are
/// the sums. The conservation law the chaos harness pins:
/// `offered == completed + rejected + shed` per tier, after a drain.
#[derive(Debug, Clone, Default)]
struct NetTotals {
    offered: [u64; Priority::COUNT],
    rejected: [u64; Priority::COUNT],
    shed: [u64; Priority::COUNT],
    completed: [u64; Priority::COUNT],
    lat_ns: Vec<u64>,
}

/// Scheduled virtual events.
enum SimEvent {
    /// An idle replica's coalescing window closed: form and start a batch.
    Dispatch { replica_id: u64 },
    /// The batch in service on this replica finished.
    Completion { replica_id: u64 },
    /// A rebound device finished reprogramming: bring up one fresh replica
    /// of `net`, tagged onto `device`'s contention group (see
    /// [`SimFleet::rebind_device`]).
    Activate { net: u32, device: u32 },
}

/// Outcome of offering one request to the fleet's bounded admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admitted onto the replica with this ordinal.
    Admitted {
        /// Ordinal of the admitting replica within its network.
        replica: usize,
    },
    /// Every replica of the network was at its cap.
    Rejected,
    /// Batch-tier request turned away with every replica past
    /// [`batch_queue_share`] of its cap: the fleet is protecting
    /// interactive headroom, NOT undersized — shed is accounted apart from
    /// `Rejected` so the SLO overload signal stays interactive-only.
    Shed,
}

/// Per-network roll-up of a finished (or running) simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct SimNetStats {
    /// Network name.
    pub network: String,
    /// Requests offered (admitted + rejected + shed).
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Requests turned away with every replica at cap (interactive tier —
    /// the fleet-too-small overload signal).
    pub rejected: u64,
    /// Batch-tier requests turned away past [`batch_queue_share`] of every
    /// cap (the fleet protecting interactive headroom; NOT overload).
    pub shed: u64,
    /// Requests completed (admitted ones still in queue at the end of a
    /// run are drained by the runner, so this equals `admitted` then).
    pub completed: u64,
    /// `offered` split by [`Priority`] tier (index = `Priority::index()`).
    pub offered_tier: [u64; Priority::COUNT],
    /// `rejected` split by tier.
    pub rejected_tier: [u64; Priority::COUNT],
    /// `shed` split by tier (only the batch slot can be nonzero).
    pub shed_tier: [u64; Priority::COUNT],
    /// `completed` split by tier.
    pub completed_tier: [u64; Priority::COUNT],
    /// rejected / offered (shed excluded by design).
    pub overload_rate: f64,
    /// Mean completion latency (virtual ms, all-time).
    pub mean_ms: f64,
    /// p95 completion latency (virtual ms, all-time, nearest-rank).
    pub p95_ms: f64,
}

/// The virtual fleet.
pub struct SimFleet {
    clock: VirtualClock,
    heap: EventHeap<SimEvent>,
    networks: Vec<String>,
    /// Interned device names (contention groups).
    devices: Vec<String>,
    replicas: Vec<SimReplica>,
    /// Indices into `replicas` of the routable (non-draining) set, in fleet
    /// order — `router` indices refer to positions in THIS vec, exactly as
    /// the live `ShardedService` pairs its router with its shard vec.
    routable: Vec<usize>,
    router: Router,
    models: BTreeMap<String, SimServiceModel>,
    totals: Vec<NetTotals>,
    contention_alpha: f64,
    next_id: u64,
    events: u64,
    /// Telemetry sink ([`crate::obs::Telemetry`] in practice): when set, the
    /// engine emits the SAME span kinds and stage samples the live
    /// coordinator does, stamped with the virtual clock — sim/live parity is
    /// pinned by `rust/tests/integration_obs.rs`.
    sink: Option<Arc<dyn Sink>>,
    /// Full telemetry attachment ([`SimFleet::set_telemetry`]): per-replica
    /// [`SpanScope`]s instead of the identity-less hub sink, plus request
    /// trace ids from the plane-wide counter.
    obs: Option<Arc<Telemetry>>,
    /// Cached hub scope used only to allocate trace ids (one `Relaxed`
    /// `fetch_add` per admission, mirroring the live shard).
    tracer: Option<SpanScope>,
}

/// Emit one span through the replica's shard scope when the fleet is
/// telemetry-attached, else through the identity-less sink. Trace-carrying
/// values arrive pre-packed; with no telemetry the id is
/// [`UNTRACED`] and `pack` leaves the payload untouched.
fn emit_span(
    scope: &Option<SpanScope>,
    sink: &Option<Arc<dyn Sink>>,
    t: SimNs,
    kind: SpanKind,
    value: u64,
) {
    if let Some(s) = scope {
        s.span_at(t, kind, value);
    } else if let Some(s) = sink {
        s.span(SpanEvent::new(t, kind, value));
    }
}

/// Stage-sample twin of [`emit_span`]: both paths land in the same shared
/// stage histograms.
fn emit_stage(
    scope: &Option<SpanScope>,
    sink: &Option<Arc<dyn Sink>>,
    stage: Stage,
    ns: u64,
) {
    if let Some(s) = scope {
        s.stage(stage, ns);
    } else if let Some(s) = sink {
        s.stage(stage, ns);
    }
}

impl SimFleet {
    /// Fleet from per-network service models (each starting at its
    /// `replicas` count, ordinals 0..n in model order).
    pub fn new(models: &[SimServiceModel]) -> Result<SimFleet> {
        if models.is_empty() {
            return Err(Error::InvalidConfig("simulated fleet needs ≥ 1 network model".into()));
        }
        let mut fleet = SimFleet {
            clock: VirtualClock::new(),
            heap: EventHeap::new(),
            networks: Vec::new(),
            devices: Vec::new(),
            replicas: Vec::new(),
            routable: Vec::new(),
            router: Router::default(),
            models: BTreeMap::new(),
            totals: Vec::new(),
            contention_alpha: DEFAULT_CONTENTION_ALPHA,
            next_id: 0,
            events: 0,
            sink: None,
            obs: None,
            tracer: None,
        };
        for m in models {
            if fleet.models.contains_key(&m.network) {
                return Err(Error::InvalidConfig(format!(
                    "duplicate service model for network `{}`",
                    m.network
                )));
            }
            fleet.models.insert(m.network.clone(), m.clone());
            fleet.intern(&m.network);
            for _ in 0..m.replicas {
                fleet.push_replica(&m.network, m.queue_cap, m.service_ns);
            }
        }
        fleet.rebuild_routing();
        Ok(fleet)
    }

    /// Set the device-contention slope (`0.0` disables contention; the
    /// default is [`DEFAULT_CONTENTION_ALPHA`]).
    pub fn set_contention_alpha(&mut self, alpha: f64) {
        self.contention_alpha = alpha.max(0.0);
    }

    /// Attach a telemetry sink: every admission, window, batch and
    /// completion emits the same span kinds / stage samples as the live
    /// coordinator, stamped with virtual time.
    pub fn set_sink(&mut self, sink: Arc<dyn Sink>) {
        self.sink = Some(sink);
    }

    /// Attach a full [`Telemetry`] plane: every replica (existing and
    /// future) records through its own `(network, replica)` [`SpanScope`] —
    /// the same per-shard rings the live coordinator fills — and every
    /// admission is stamped with a request trace id from the plane-wide
    /// counter, packed into the per-request span values exactly as the live
    /// shard packs them (`docs/HOTPATH.md` §10). Prefer this over
    /// [`SimFleet::set_sink`] whenever per-replica attribution,
    /// [`crate::obs::trace::assemble`] or
    /// [`crate::obs::drift::DriftMonitor`] will consume the spans.
    pub fn set_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        for i in 0..self.replicas.len() {
            let name = self.networks[self.replicas[i].net as usize].clone();
            let ordinal = self.replicas[i].replica;
            self.replicas[i].scope = Some(telemetry.scope_for(&name, ordinal));
        }
        self.tracer = Some(telemetry.hub_scope());
        self.obs = Some(telemetry);
    }

    fn intern(&mut self, network: &str) -> u32 {
        match self.networks.iter().position(|n| n == network) {
            Some(i) => i as u32,
            None => {
                self.networks.push(network.to_string());
                self.totals.push(NetTotals::default());
                (self.networks.len() - 1) as u32
            }
        }
    }

    fn intern_device(&mut self, device: &str) -> u32 {
        match self.devices.iter().position(|d| d == device) {
            Some(i) => i as u32,
            None => {
                self.devices.push(device.to_string());
                (self.devices.len() - 1) as u32
            }
        }
    }

    /// Append one replica (ordinal = highest existing + 1, draining
    /// included — exactly the live `add_shard` ordinal rule). Batching,
    /// window and device placement come from the network's registered
    /// [`SimServiceModel`] when one exists. Public so tests can build
    /// heterogeneous-cap fleets; `scale_up` uses it too.
    pub fn push_replica(&mut self, network: &str, queue_cap: usize, service_ns: u64) -> usize {
        let (mut policy, platform, util_frac) = match self.models.get(network) {
            Some(m) => (m.policy(), m.platform.clone(), m.util_frac),
            None => (
                CoalescePolicy { idle_window_ns: 0, service_ns: 0, fill_ns: 0, max_batch: 1 },
                None,
                0.0,
            ),
        };
        // The caller's service time wins over the model's (tests build
        // heterogeneous-rate fleets this way); re-clamp the fill under it.
        policy.service_ns = service_ns.max(1);
        policy.fill_ns = policy.fill_ns.min(policy.service_ns - 1);
        let net = self.intern(network);
        let device = platform.as_deref().map(|p| self.intern_device(p));
        let ordinal = self
            .replicas
            .iter()
            .filter(|r| r.net == net)
            .map(|r| r.replica + 1)
            .max()
            .unwrap_or(0);
        let id = self.next_id;
        self.next_id += 1;
        let scope = self.obs.as_ref().map(|t| t.scope_for(network, ordinal));
        self.replicas.push(SimReplica {
            id,
            net,
            replica: ordinal,
            queue_cap: queue_cap.max(1),
            policy,
            device,
            util_frac,
            scope,
            queues: [VecDeque::new(), VecDeque::new()],
            wfq: WfqState::new(),
            wedged_until: 0,
            in_flight: Vec::new(),
            window_opened_at: 0,
            dispatch_at: None,
            dispatched_at: 0,
            served: 0,
            batches: 0,
            rejected: 0,
            draining: false,
            started_at: self.clock.now(),
            lat_win_ns: Vec::new(),
            lat_next: 0,
        });
        self.rebuild_routing();
        ordinal
    }

    fn rebuild_routing(&mut self) {
        self.routable = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.draining)
            .map(|(i, _)| i)
            .collect();
        let networks = &self.networks;
        let replicas = &self.replicas;
        self.router =
            Router::new(self.routable.iter().map(|&i| networks[replicas[i].net as usize].as_str()));
    }

    /// Current virtual time (ns).
    pub fn now_ns(&self) -> SimNs {
        self.clock.now()
    }

    /// Current virtual time (ms).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Events processed so far (arrivals + dispatches + completions +
    /// control ticks).
    pub fn events_processed(&self) -> u64 {
        self.events
    }

    /// Service events (dispatches + completions) still scheduled.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Virtual time of the next scheduled service event.
    pub fn next_completion_at(&self) -> Option<SimNs> {
        self.heap.peek_at()
    }

    /// Routable replicas of `network` right now.
    pub fn replica_count(&self, network: &str) -> usize {
        self.router.replicas(network).len()
    }

    /// Routable replica counts per network (sorted by name).
    pub fn replica_counts(&self) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        for &i in &self.routable {
            let name = self.networks[self.replicas[i].net as usize].clone();
            *out.entry(name).or_insert(0) += 1;
        }
        out
    }

    /// Co-located utilization share on `device` (summed over EVERY replica
    /// still occupying silicon — draining ones included).
    fn device_load(&self, device: u32) -> f64 {
        self.replicas
            .iter()
            .filter(|r| r.device == Some(device))
            .map(|r| r.util_frac)
            .sum()
    }

    /// Contention slowdown for one replica: 1 + α × (co-located share
    /// excluding itself). A lone replica (or one without a device tag)
    /// serves at exactly the model-predicted rate.
    fn contention_factor(&self, idx: usize) -> f64 {
        let r = &self.replicas[idx];
        match r.device {
            Some(d) => {
                let others = (self.device_load(d) - r.util_frac).max(0.0);
                1.0 + self.contention_alpha * others
            }
            None => 1.0,
        }
    }

    /// Process every service event scheduled at or before `t`, then advance
    /// the clock to `t`.
    pub fn run_until(&mut self, t: SimNs) {
        while let Some(at) = self.heap.peek_at() {
            if at > t {
                break;
            }
            let (at, ev) = self.heap.pop().expect("peeked");
            self.service_event(at, ev);
        }
        self.clock.advance_to(t);
    }

    /// Process every remaining service event (advancing the clock with
    /// each) until all admitted requests have completed.
    pub fn drain(&mut self) {
        while let Some((at, ev)) = self.heap.pop() {
            self.service_event(at, ev);
        }
    }

    /// Form a batch on `idx` at virtual time `now` and schedule its
    /// completion. No-op when the queue is empty.
    fn dispatch(&mut self, idx: usize, now: SimNs) {
        let factor = self.contention_factor(idx);
        let r = &mut self.replicas[idx];
        if now < r.wedged_until {
            // Wedged worker: the batch that would form now defers to the
            // wake time. Re-arm through the `dispatch_at` guard so any
            // earlier deadline still in the heap goes stale.
            let wake = r.wedged_until;
            r.dispatch_at = Some(wake);
            let id = r.id;
            self.heap.push(wake, SimEvent::Dispatch { replica_id: id });
            return;
        }
        r.dispatch_at = None;
        let b = r.queued().min(r.policy.max_batch);
        if b == 0 {
            return;
        }
        r.in_flight.clear();
        // Weighted fair selection across tiers, FIFO within each — the
        // same `WfqState` law the live worker's carry runs, so a mixed
        // backlog forms the identical batch on both planes.
        for _ in 0..b {
            let nonempty =
                [!r.queues[0].is_empty(), !r.queues[1].is_empty()];
            let p = r.wfq.pick(nonempty).expect("b > 0: some tier is nonempty");
            let (arrived, tid) =
                r.queues[p.index()].pop_front().expect("picked tier is nonempty");
            r.in_flight.push((arrived, tid, p));
        }
        r.batches += 1;
        r.dispatched_at = now;
        // Same per-batch emission as the live worker: the window closes,
        // the coalesce hold is sampled, the batch starts, and each rider
        // samples its enqueue → dispatch wait. Batch-scoped span values
        // stay plain batch sizes (a batch has no single trace id).
        emit_span(&r.scope, &self.sink, now, SpanKind::WindowClose, b as u64);
        emit_stage(&r.scope, &self.sink, Stage::Coalesce, now.saturating_sub(r.window_opened_at));
        emit_span(&r.scope, &self.sink, now, SpanKind::BatchStart, b as u64);
        for &(arrived, _, _) in &r.in_flight {
            emit_stage(&r.scope, &self.sink, Stage::QueueWait, now.saturating_sub(arrived));
        }
        let base = r.policy.batch_ns(b as u64);
        let service = if factor <= 1.0 {
            base
        } else {
            ((base as f64 * factor).round() as u64).max(base)
        };
        let id = r.id;
        self.heap.push(now.saturating_add(service), SimEvent::Completion { replica_id: id });
    }

    /// Open (or reopen) a coalescing window on `idx` over its current
    /// backlog at virtual time `now`, dispatching straight away when the
    /// policy owes the backlog no wait.
    fn open_window(&mut self, idx: usize, now: SimNs) {
        let r = &mut self.replicas[idx];
        // Opened unconditionally (even for zero-width windows): the live
        // worker stamps the open on the first recv, before it knows the
        // window will close instantly, so per-batch span counts match.
        r.window_opened_at = now;
        emit_span(&r.scope, &self.sink, now, SpanKind::WindowOpen, 1);
        let w = r.policy.window_ns(r.queued());
        if w == 0 {
            self.dispatch(idx, now);
        } else {
            let deadline = now.saturating_add(w);
            r.dispatch_at = Some(deadline);
            let id = r.id;
            self.heap.push(deadline, SimEvent::Dispatch { replica_id: id });
        }
    }

    fn service_event(&mut self, at: SimNs, ev: SimEvent) {
        self.clock.advance_to(at);
        self.events += 1;
        let (replica_id, is_completion) = match ev {
            SimEvent::Dispatch { replica_id } => (replica_id, false),
            SimEvent::Completion { replica_id } => (replica_id, true),
            SimEvent::Activate { net, device } => {
                self.activate(net, device);
                return;
            }
        };
        let idx = match self.replicas.iter().position(|r| r.id == replica_id) {
            Some(i) => i,
            None => {
                // A superseded Dispatch deadline can outlive its replica
                // (window extended, batch ran, idle replica removed);
                // completions cannot — draining keeps the replica alive.
                assert!(!is_completion, "completion event for a removed replica");
                return;
            }
        };
        if !is_completion {
            // Extended windows leave their earlier deadlines in the heap;
            // only the event matching the replica's CURRENT deadline fires.
            if self.replicas[idx].dispatch_at != Some(at) {
                return;
            }
            self.dispatch(idx, at);
            return;
        }
        let (net, batch, remove, dispatched_at) = {
            let r = &mut self.replicas[idx];
            let batch: Vec<(SimNs, u32, Priority)> = std::mem::take(&mut r.in_flight);
            r.served += batch.len() as u64;
            for &(arrived, _, _) in &batch {
                r.record_latency((at - arrived).max(1));
            }
            (r.net as usize, batch, r.draining && r.outstanding() == 0, r.dispatched_at)
        };
        {
            let scope = &self.replicas[idx].scope;
            emit_span(scope, &self.sink, at, SpanKind::BatchEnd, batch.len() as u64);
            emit_stage(scope, &self.sink, Stage::Exec, at.saturating_sub(dispatched_at));
            // One guard-release per rider, as each live reply path frees its
            // admission slot — packed with the rider's trace id so
            // `obs::trace::assemble` can close the request.
            for &(_, tid, _) in &batch {
                emit_span(scope, &self.sink, at, SpanKind::GuardRelease, pack(tid, 0));
            }
        }
        let t = &mut self.totals[net];
        for (arrived, _, p) in batch {
            t.completed[p.index()] += 1;
            t.lat_ns.push((at - arrived).max(1));
        }
        if remove {
            self.replicas.remove(idx);
            self.rebuild_routing();
        } else if self.replicas[idx].queued() > 0 {
            // Backlog absorbed at completion is owed `window_ns(backlog)`
            // from this instant — the live worker drains the channel and
            // only then opens a deadline for MORE arrivals. A full (or
            // window-less) backlog dispatches immediately.
            self.open_window(idx, at);
        }
    }

    /// Offer one interactive request to `network`'s bounded admission at
    /// virtual time `at` — [`SimFleet::offer_prioritized`] with
    /// [`Priority::Interactive`], the pre-tier engine's exact behavior.
    pub fn offer(&mut self, network: &str, at: SimNs) -> Result<Admission> {
        self.offer_prioritized(network, at, Priority::Interactive)
    }

    /// Offer one request to `network`'s bounded admission at virtual time
    /// `at`: due service events are processed first, then the replicas are
    /// tried in load order (fewest outstanding, lowest fleet index on ties
    /// — the live `try_submit` fallback walk). The tier sets the cap it is
    /// admitted under, exactly the live shard's `try_acquire` law:
    /// interactive uses the full replica cap and is `Rejected` only when
    /// EVERY replica is at it (one rejection charged to the preferred
    /// replica) — or when none is routable at all, a device outage mid
    /// rebind or chaos run; batch is admitted only below
    /// [`batch_queue_share`] of each cap and is `Shed` past every share.
    pub fn offer_prioritized(
        &mut self,
        network: &str,
        at: SimNs,
        priority: Priority,
    ) -> Result<Admission> {
        self.run_until(at);
        self.events += 1;
        let net = self.networks.iter().position(|n| n == network).ok_or_else(|| {
            Error::Usage(format!("no simulated replica serves network `{network}`"))
        })? as usize;
        self.totals[net].offered[priority.index()] += 1;
        let replicas = &self.replicas;
        let routable = &self.routable;
        // A known network can be momentarily unrouted (device outage,
        // rebind downtime): the offer is then the admission failure
        // itself, not a usage error — the empty order falls through to the
        // tier's rejection/shed arm exactly as if every replica were at
        // cap.
        let order = self
            .router
            .route_all_by(network, |ri| replicas[routable[ri]].outstanding())
            .unwrap_or_default();
        for &ri in &order {
            let idx = self.routable[ri];
            let r = &mut self.replicas[idx];
            let cap = match priority {
                Priority::Interactive => r.queue_cap,
                Priority::Batch => batch_queue_share(r.queue_cap),
            };
            if r.outstanding() < cap {
                // Trace id from the plane-wide counter, exactly as the live
                // shard allocates at admission; UNTRACED (0) when the fleet
                // is unobserved, which `pack` passes through untouched.
                let tid = match &self.tracer {
                    Some(t) => t.next_trace_id(),
                    None => UNTRACED,
                };
                r.queues[priority.index()].push_back((at, tid));
                let ordinal = r.replica;
                // Admission spans in the live shard's order: Route (chosen
                // ordinal), then Enqueue (outstanding after the push) —
                // payloads packed under the request's trace id.
                emit_span(&r.scope, &self.sink, at, SpanKind::Route, pack(tid, ordinal as u64));
                emit_span(
                    &r.scope,
                    &self.sink,
                    at,
                    SpanKind::Enqueue,
                    pack(tid, r.outstanding() as u64),
                );
                if r.in_flight.is_empty() {
                    match r.dispatch_at {
                        // Idle replica: this request opens the window.
                        None => self.open_window(idx, at),
                        // Window already open: dispatch the instant the
                        // batch fills, else extend the deadline to
                        // `window_ns(queued)` past the window's opening
                        // (monotone in the backlog, so it never moves
                        // earlier; the superseded event goes stale).
                        Some(current) => {
                            let queued = r.queued();
                            if queued >= r.policy.max_batch {
                                self.dispatch(idx, at);
                            } else {
                                let deadline = r
                                    .window_opened_at
                                    .saturating_add(r.policy.window_ns(queued));
                                if deadline > current {
                                    r.dispatch_at = Some(deadline);
                                    let id = r.id;
                                    self.heap
                                        .push(deadline, SimEvent::Dispatch { replica_id: id });
                                }
                            }
                        }
                    }
                }
                return Ok(Admission::Admitted { replica: ordinal });
            }
        }
        match priority {
            Priority::Interactive => {
                if let Some(&first) = order.first() {
                    self.replicas[self.routable[first]].rejected += 1;
                }
                self.totals[net].rejected[priority.index()] += 1;
                Ok(Admission::Rejected)
            }
            // Batch past every replica's share is shed, never rejected —
            // the live shard's `note_shed`, kept out of the per-replica
            // `rejected` counter the SLO tracker reads as overload.
            Priority::Batch => {
                self.totals[net].shed[priority.index()] += 1;
                Ok(Admission::Shed)
            }
        }
    }

    /// Wedge `network`'s replica `ordinal` until virtual time `until`: a
    /// stalled worker whose in-flight batch still completes, whose queues
    /// stop draining (new dispatches defer to the wake), and whose
    /// `stats()` row stays an instant memory read — the live wedged-worker
    /// stale-stats behavior, on the virtual clock. Extends (never
    /// shortens) an existing stall. Returns false when no such replica
    /// exists.
    pub fn wedge_replica(&mut self, network: &str, ordinal: usize, until: SimNs) -> bool {
        let Some(net) = self.networks.iter().position(|n| n == network) else {
            return false;
        };
        let net = net as u32;
        for r in &mut self.replicas {
            if r.net == net && r.replica == ordinal {
                r.wedged_until = r.wedged_until.max(until);
                return true;
            }
        }
        false
    }

    /// Count one control tick as a virtual event (the runner calls this at
    /// every controller invocation so "events" covers the whole run).
    pub fn note_tick(&mut self) {
        self.events += 1;
    }

    /// Distinct (sorted) network names with a routable replica on `device`
    /// — the blast radius the chaos harness records for a device fault
    /// before applying it.
    pub fn networks_on_device(&self, device: &str) -> Vec<String> {
        let Some(d) = self.devices.iter().position(|x| x == device) else {
            return Vec::new();
        };
        let d = d as u32;
        let mut out: Vec<String> = Vec::new();
        for r in &self.replicas {
            if r.device == Some(d) && !r.draining {
                let name = &self.networks[r.net as usize];
                if !out.contains(name) {
                    out.push(name.clone());
                }
            }
        }
        out.sort();
        out
    }

    /// Take every replica on `device` out of service *drain-safely*: each is
    /// unrouted immediately, but replicas with admitted work keep serving
    /// until their backlog completes — no in-flight virtual request is ever
    /// dropped, exactly the live `remove_shard` drain semantics, applied to
    /// a whole contention group at once (a device loss or the tear-down half
    /// of a rebind). Unlike [`SimFleet::scale_down`] this deliberately
    /// bypasses the last-replica refusal: a dead device holds nothing.
    /// Returns how many replicas were taken out.
    pub fn fail_device(&mut self, device: &str) -> usize {
        let Some(d) = self.devices.iter().position(|x| x == device) else {
            return 0;
        };
        let d = d as u32;
        let mut hit = 0usize;
        let mut i = 0usize;
        while i < self.replicas.len() {
            let r = &mut self.replicas[i];
            if r.device == Some(d) && !r.draining {
                hit += 1;
                if r.outstanding() == 0 {
                    // Idle: gone at once. A stale Dispatch deadline left in
                    // the heap is recognized and ignored by `service_event`.
                    self.replicas.remove(i);
                    continue;
                }
                r.draining = true;
            }
            i += 1;
        }
        if hit > 0 {
            self.rebuild_routing();
        }
        hit
    }

    /// Reprogram `device` with `network`'s bitstream: drain-safely tear down
    /// whatever the device currently serves ([`SimFleet::fail_device`]),
    /// then pay `downtime_ms` of virtual outage before `replicas` fresh
    /// replicas activate — the reconfiguration cost the controller amortized
    /// ([`crate::fleetplan::ReconfigPolicy`]) made physical on the virtual
    /// clock. Returns how many old replicas were drained away.
    pub fn rebind_device(
        &mut self,
        device: &str,
        network: &str,
        replicas: usize,
        downtime_ms: f64,
    ) -> Result<usize> {
        if !self.models.contains_key(network) {
            return Err(Error::InvalidConfig(format!(
                "no simulated service model for network `{network}`"
            )));
        }
        let net = self.intern(network);
        let dev = self.intern_device(device);
        let drained = self.fail_device(device);
        let at = self
            .clock
            .now()
            .saturating_add((downtime_ms.max(0.0) * 1e6) as SimNs);
        for _ in 0..replicas.max(1) {
            self.heap.push(at, SimEvent::Activate { net, device: dev });
        }
        Ok(drained)
    }

    /// An `Activate` event fired: one fresh replica of `net` comes up on
    /// `device` (overriding the model's home platform — the whole point of a
    /// rebind is that the network now runs somewhere else).
    fn activate(&mut self, net: u32, device: u32) {
        let name = self.networks[net as usize].clone();
        let (queue_cap, service_ns) = match self.models.get(&name) {
            Some(m) => (m.queue_cap, m.service_ns),
            None => (1, 1),
        };
        self.push_replica(&name, queue_cap, service_ns);
        let r = self.replicas.last_mut().expect("push_replica appended");
        r.device = Some(device);
    }

    /// Synthesize the live stats plane's [`ShardedStats`] from the virtual
    /// queues: one row per routable replica, fleet-order, with the same
    /// counters the SLO tracker consumes (`requests` = completions,
    /// `rejected` live even under load, windowed latency percentiles).
    pub fn stats(&self) -> ShardedStats {
        let now = self.clock.now();
        let shards: Vec<ShardStats> = self
            .routable
            .iter()
            .map(|&i| {
                let r = &self.replicas[i];
                let (mean_ns, p95_ns) = window_mean_p95(&r.lat_win_ns);
                let (mean_ms, p95_ms) = (mean_ns / 1e6, p95_ns as f64 / 1e6);
                let elapsed_s = now.saturating_sub(r.started_at) as f64 / 1e9;
                ShardStats {
                    network: self.networks[r.net as usize].clone(),
                    replica: r.replica,
                    queue_depth: r.outstanding() as u64,
                    queue_cap: r.queue_cap as u64,
                    rejected: r.rejected,
                    stale: false,
                    service: ServiceStats {
                        requests: r.served,
                        errors: 0,
                        batches: r.batches,
                        mean_latency_ms: mean_ms,
                        p95_latency_ms: p95_ms,
                        throughput_rps: if elapsed_s > 0.0 {
                            r.served as f64 / elapsed_s
                        } else {
                            0.0
                        },
                        parallelism: 1,
                    },
                }
            })
            .collect();
        let fleet = aggregate(&shards);
        ShardedStats { shards, fleet }
    }

    /// All-time per-network roll-up (sorted by network name).
    pub fn network_stats(&self) -> Vec<SimNetStats> {
        let mut order: Vec<usize> = (0..self.networks.len()).collect();
        order.sort_by(|&a, &b| self.networks[a].cmp(&self.networks[b]));
        order
            .into_iter()
            .map(|i| {
                let t = &self.totals[i];
                let (mean_ns, p95_ns) = window_mean_p95(&t.lat_ns);
                let (mean_ms, p95_ms) = (mean_ns / 1e6, p95_ns as f64 / 1e6);
                let offered: u64 = t.offered.iter().sum();
                let rejected: u64 = t.rejected.iter().sum();
                let shed: u64 = t.shed.iter().sum();
                SimNetStats {
                    network: self.networks[i].clone(),
                    offered,
                    admitted: offered - rejected - shed,
                    rejected,
                    shed,
                    completed: t.completed.iter().sum(),
                    offered_tier: t.offered,
                    rejected_tier: t.rejected,
                    shed_tier: t.shed,
                    completed_tier: t.completed,
                    overload_rate: if offered == 0 {
                        0.0
                    } else {
                        rejected as f64 / offered as f64
                    },
                    mean_ms,
                    p95_ms,
                }
            })
            .collect()
    }

    /// The fleet's current model expectations for
    /// [`crate::obs::drift::DriftMonitor`]: one [`ModelExpectation`] per
    /// registered network, with the contention share `x` read off the
    /// ACTUAL device packing (mean over the network's replicas of the
    /// co-located share excluding self — the same quantity
    /// `contention_factor` stretches by) and `alpha` set to whatever the
    /// monitor should ASSUME (usually the shipped calibration, not
    /// necessarily the slope this fleet really runs with — the gap between
    /// the two is exactly what the watchdog exists to catch).
    pub fn drift_expectations(&self, assumed_alpha: f64) -> Vec<ModelExpectation> {
        self.models
            .values()
            .map(|m| {
                let shares: Vec<f64> = self
                    .replicas
                    .iter()
                    .filter(|r| self.networks[r.net as usize] == m.network)
                    .map(|r| match r.device {
                        Some(d) => (self.device_load(d) - r.util_frac).max(0.0),
                        None => 0.0,
                    })
                    .collect();
                let x = if shares.is_empty() {
                    0.0
                } else {
                    shares.iter().sum::<f64>() / shares.len() as f64
                };
                ModelExpectation {
                    network: m.network.clone(),
                    service_ns: m.service_ns,
                    fill_ns: m.fill_ns,
                    contention_x: x,
                    alpha: assumed_alpha,
                }
            })
            .collect()
    }
}

impl ScaleTarget for SimFleet {
    fn observe(&mut self) -> ShardedStats {
        self.stats()
    }

    fn scale_up(&mut self, template: &ShardSpec) -> Result<()> {
        let model = self.models.get(&template.network).cloned().ok_or_else(|| {
            Error::InvalidConfig(format!(
                "no simulated service model for network `{}`",
                template.network
            ))
        })?;
        self.push_replica(&template.network, template.queue_cap, model.service_ns);
        Ok(())
    }

    fn scale_down(&mut self, network: &str) -> Result<()> {
        // Mirror `ShardedService::remove_shard`: highest-ordinal routable
        // replica, refuse to remove the last one, unroute first and let
        // in-flight virtual requests drain.
        let mut pick: Option<usize> = None;
        let mut count = 0usize;
        for &i in &self.routable {
            let r = &self.replicas[i];
            if self.networks[r.net as usize] == network {
                count += 1;
                match pick {
                    Some(j) if self.replicas[j].replica >= r.replica => {}
                    _ => pick = Some(i),
                }
            }
        }
        let idx = pick
            .ok_or_else(|| Error::Usage(format!("no shard serves network `{network}`")))?;
        if count == 1 {
            return Err(Error::InvalidConfig(format!(
                "refusing to remove the last replica of `{network}`"
            )));
        }
        if self.replicas[idx].outstanding() == 0 {
            self.replicas.remove(idx);
        } else {
            self.replicas[idx].draining = true;
        }
        self.rebuild_routing();
        Ok(())
    }

    fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// A controller-emitted rebind becomes a physical sequence on the
    /// virtual clock: drain the device, wait out the reprogramming outage,
    /// activate the fresh replicas ([`SimFleet::rebind_device`]).
    fn rebind(&mut self, device: &str, spec: &ShardSpec, downtime_ms: f64) -> Result<()> {
        self.rebind_device(device, &spec.network, spec.replicas.max(1), downtime_ms)
            .map(|_| ())
    }
}

/// Options for one simulation run.
#[derive(Debug, Clone)]
pub struct SimRunOptions {
    /// Virtual time between controller invocations (ms).
    pub control_interval_ms: f64,
    /// Extra calm control ticks after the trace drains (lets idle
    /// hysteresis produce the scale-down tail of the replica trajectory).
    pub cooldown_ticks: usize,
}

impl Default for SimRunOptions {
    fn default() -> Self {
        SimRunOptions { control_interval_ms: 50.0, cooldown_ticks: 6 }
    }
}

/// One `(virtual time, network, replicas)` sample of the replica
/// trajectory (recorded at start and whenever a count changes).
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryPoint {
    /// Virtual time of the sample (ms).
    pub t_ms: f64,
    /// Network.
    pub network: String,
    /// Routable replicas at that instant.
    pub replicas: usize,
}

/// The outcome of replaying one trace through a [`SimFleet`].
#[derive(Debug, Clone)]
pub struct SimRun {
    /// Virtual events processed (arrivals + service events + control ticks).
    pub events: u64,
    /// Requests offered across all networks.
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Requests rejected at admission (interactive overload).
    pub rejected: u64,
    /// Batch-tier requests shed at admission (interactive protection).
    pub shed: u64,
    /// Requests completed.
    pub completed: u64,
    /// Virtual end time of the run (ms).
    pub virtual_ms: f64,
    /// Per-network roll-ups (sorted by name).
    pub networks: Vec<SimNetStats>,
    /// Every controller decision, stamped with virtual time.
    pub decisions: Vec<ScaleDecision>,
    /// Replica trajectory (initial counts + every change).
    pub trajectory: Vec<TrajectoryPoint>,
}

/// Replay `trace` through `fleet`, invoking each of `scalers` every
/// `control_interval_ms` of *virtual* time (the same
/// [`Autoscaler::step_target`] path the live autoscaler runs — pass an
/// empty slice for an uncontrolled run). Deterministic: same fleet, trace
/// and scaler state ⇒ identical [`SimRun`].
pub fn simulate_trace(
    fleet: &mut SimFleet,
    trace: &Trace,
    scalers: &mut [Autoscaler],
    opts: &SimRunOptions,
) -> Result<SimRun> {
    let interval = ((opts.control_interval_ms.max(1e-3)) * 1e6) as SimNs;
    let mut next_tick = fleet.now_ns() + interval;
    let mut decisions: Vec<ScaleDecision> = Vec::new();
    let mut trajectory: Vec<TrajectoryPoint> = Vec::new();
    let mut last_counts = fleet.replica_counts();
    for (net, n) in &last_counts {
        trajectory.push(TrajectoryPoint {
            t_ms: fleet.now_ms(),
            network: net.clone(),
            replicas: *n,
        });
    }

    fn tick(
        fleet: &mut SimFleet,
        scalers: &mut [Autoscaler],
        decisions: &mut Vec<ScaleDecision>,
        trajectory: &mut Vec<TrajectoryPoint>,
        last_counts: &mut BTreeMap<String, usize>,
    ) -> Result<()> {
        fleet.note_tick();
        for sc in scalers.iter_mut() {
            decisions.extend(sc.step_target(fleet)?);
        }
        let counts = fleet.replica_counts();
        if counts != *last_counts {
            let t_ms = fleet.now_ms();
            for (net, n) in &counts {
                if last_counts.get(net) != Some(n) {
                    trajectory.push(TrajectoryPoint {
                        t_ms,
                        network: net.clone(),
                        replicas: *n,
                    });
                }
            }
            *last_counts = counts;
        }
        Ok(())
    }

    for ev in &trace.events {
        while !scalers.is_empty() && next_tick <= ev.at_ns {
            fleet.run_until(next_tick);
            tick(fleet, scalers, &mut decisions, &mut trajectory, &mut last_counts)?;
            next_tick += interval;
        }
        fleet.offer(trace.network_of(ev), ev.at_ns)?;
    }
    // Drain the backlog, still honouring the control cadence.
    while let Some(at) = fleet.next_completion_at() {
        if !scalers.is_empty() && next_tick <= at {
            fleet.run_until(next_tick);
            tick(fleet, scalers, &mut decisions, &mut trajectory, &mut last_counts)?;
            next_tick += interval;
        } else {
            fleet.run_until(at);
        }
    }
    // Cooldown: a calm tail so idle hysteresis can fire.
    if !scalers.is_empty() {
        for _ in 0..opts.cooldown_ticks {
            fleet.run_until(next_tick);
            tick(fleet, scalers, &mut decisions, &mut trajectory, &mut last_counts)?;
            next_tick += interval;
        }
    }

    let networks = fleet.network_stats();
    let (mut offered, mut admitted, mut rejected, mut shed, mut completed) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for n in &networks {
        offered += n.offered;
        admitted += n.admitted;
        rejected += n.rejected;
        shed += n.shed;
        completed += n.completed;
    }
    Ok(SimRun {
        events: fleet.events_processed(),
        offered,
        admitted,
        rejected,
        shed,
        completed,
        virtual_ms: fleet.now_ms(),
        networks,
        decisions,
        trajectory,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulate::workload::{Scenario, ScenarioShape};

    fn two_net_models() -> Vec<SimServiceModel> {
        vec![
            SimServiceModel::new("a", 0.002, 4, 2),
            SimServiceModel::new("b", 0.001, 4, 1),
        ]
    }

    #[test]
    fn offer_routes_and_completes_on_virtual_time() {
        let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 8, 1)]).unwrap();
        assert_eq!(f.offer("a", 0).unwrap(), Admission::Admitted { replica: 0 });
        assert_eq!(f.pending(), 1);
        // 1 ms service: completion at t = 1e6 ns.
        f.run_until(999_999);
        assert_eq!(f.pending(), 1);
        f.run_until(1_000_000);
        assert_eq!(f.pending(), 0);
        let s = f.stats();
        assert_eq!(s.shards[0].service.requests, 1);
        assert!((s.shards[0].service.p95_latency_ms - 1.0).abs() < 1e-3);
        // Virtual time advanced with zero wall sleeping.
        assert!((f.now_ms() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn queueing_delay_shows_up_in_latency() {
        // Two back-to-back arrivals on a 1-replica, 1 ms service: the
        // second waits behind the first.
        let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 8, 1)]).unwrap();
        f.offer("a", 0).unwrap();
        f.offer("a", 0).unwrap();
        f.drain();
        let ns = f.network_stats();
        assert_eq!(ns[0].completed, 2);
        assert!((ns[0].p95_ms - 2.0).abs() < 1e-3, "queued request saw 2 ms: {ns:?}");
    }

    #[test]
    fn backlog_coalesces_into_model_priced_batches() {
        // 1 ms service with a 0.4 ms amortizable fill, batches of up to 4.
        // Five arrivals at t = 0: the first dispatches alone (the queue was
        // empty — live recv blocks for the first request), the remaining
        // four coalesce into ONE batch when it completes.
        let model = SimServiceModel::new("a", 1.0, 8, 1).with_batching(4, 0.4);
        let mut f = SimFleet::new(&[model]).unwrap();
        for _ in 0..5 {
            f.offer("a", 0).unwrap();
        }
        f.drain();
        let s = f.stats();
        assert_eq!(s.shards[0].service.requests, 5);
        assert_eq!(s.shards[0].service.batches, 2, "1 + 4, not 5 singles");
        // Batch 1: 1 ms (b = 1). Batch 2: 0.4 + 4×0.6 = 2.8 ms, done at
        // t = 3.8 ms — the amortized curve, NOT 4 further service times.
        let ns = f.network_stats();
        assert!((ns[0].p95_ms - 3.8).abs() < 1e-3, "{ns:?}");
        assert!((f.now_ms() - 3.8).abs() < 1e-6);
    }

    #[test]
    fn coalescing_window_delays_the_first_dispatch_to_absorb_arrivals() {
        // A 0.5 ms idle window: two arrivals 0.2 ms apart ride ONE batch —
        // and absorbing the second EXTENDS the window by one fill (the
        // adaptive law), exactly as `coalesce::schedule` predicts.
        let model =
            SimServiceModel::new("a", 1.0, 8, 1).with_batching(4, 0.4).with_window_ms(0.5);
        let mut f = SimFleet::new(&[model]).unwrap();
        f.offer("a", 0).unwrap();
        f.offer("a", 200_000).unwrap();
        f.drain();
        let s = f.stats();
        assert_eq!(s.shards[0].service.batches, 1, "window coalesced both");
        // window_ns(2) = 0.5 + 0.4 = 0.9 ms, so dispatch at 0.9 ms +
        // batch(2) = 0.4 + 2×0.6 = 1.6 ms → done at 2.5 ms.
        assert!((f.now_ms() - 2.5).abs() < 1e-6, "{}", f.now_ms());
    }

    #[test]
    fn adaptive_sim_matches_the_policy_reference_interpreter() {
        // The tentpole parity requirement: on a deterministic arrival trace
        // (strictly increasing timestamps, one replica), the event-driven
        // engine must produce EXACTLY the batch schedule of
        // `coalesce::schedule`, the shared policy's pure interpreter —
        // covering idle windows, backlog-stretched windows, fill-the-batch
        // dispatch and backlog absorbed at completion.
        use crate::coordinator::schedule;
        let model =
            SimServiceModel::new("a", 1.0, 64, 1).with_batching(4, 0.4).with_window_ms(0.5);
        let policy = model.policy();
        let arrivals: Vec<u64> = vec![
            0, 200_000, 350_000, 1_900_000, 2_000_000, 2_050_000, 2_100_000, 6_000_000,
            9_500_000, 9_600_000,
        ];
        let mut f = SimFleet::new(&[model]).unwrap();
        for &at in &arrivals {
            assert_eq!(f.offer("a", at).unwrap(), Admission::Admitted { replica: 0 });
        }
        f.drain();
        let reference = schedule(&policy, &arrivals);
        assert_eq!(
            reference.iter().map(|b| b.size).collect::<Vec<_>>(),
            vec![3, 4, 1, 2],
            "the trace exercises every regime"
        );
        let s = f.stats();
        assert_eq!(s.shards[0].service.batches, reference.len() as u64);
        assert_eq!(
            s.shards[0].service.requests,
            reference.iter().map(|b| b.size as u64).sum::<u64>()
        );
        assert_eq!(
            f.now_ns(),
            reference.last().unwrap().complete_ns,
            "virtual clock ends at the reference schedule's last completion"
        );
    }

    #[test]
    fn colocated_replicas_contend_for_the_device() {
        // Two fleets, identical except co-location: 2 replicas each using
        // 30% of one device vs 2 uncontended replicas. One request per
        // replica at t = 0.
        let packed = SimServiceModel::new("a", 1.0, 8, 2).on_platform("ZCU104", 0.3);
        let mut f = SimFleet::new(&[packed]).unwrap();
        // Pin the slope: the default is the host-calibrated value, and this
        // test checks the contention FORMULA, not the calibration.
        f.set_contention_alpha(0.5);
        f.offer("a", 0).unwrap();
        f.offer("a", 0).unwrap();
        f.drain();
        // factor = 1 + 0.5 × 0.3 (the OTHER replica's share) = 1.15.
        assert!((f.now_ms() - 1.15).abs() < 1e-6, "{}", f.now_ms());

        let mut lone = SimFleet::new(&[SimServiceModel::new("a", 1.0, 8, 2)]).unwrap();
        lone.offer("a", 0).unwrap();
        lone.offer("a", 0).unwrap();
        lone.drain();
        assert!((lone.now_ms() - 1.0).abs() < 1e-9, "uncontended replicas run at rate");
    }

    #[test]
    fn contention_slowdown_is_monotone_in_colocated_count() {
        let mut last = 0.0f64;
        for n in 1..=4usize {
            let model = SimServiceModel::new("a", 1.0, 8, n).on_platform("dev", 0.2);
            let mut f = SimFleet::new(&[model]).unwrap();
            for _ in 0..n {
                f.offer("a", 0).unwrap();
            }
            f.drain();
            // One request per replica, all parallel: makespan = one
            // contended service time, growing with each co-located replica.
            let makespan = f.now_ms();
            assert!(
                makespan > last,
                "packing must slow the device monotonically: {makespan} after {last}"
            );
            last = makespan;
        }
    }

    #[test]
    fn bounded_admission_rejects_only_when_every_replica_is_full() {
        // Mirror of the live `try_submit_falls_back_across_replicas` test:
        // caps 1 and 4, nothing completes (huge service time).
        let mut f = SimFleet::new(&[SimServiceModel {
            service_ns: u64::MAX / 4,
            ..SimServiceModel::new("net", 1.0, 1, 0)
        }])
        .unwrap();
        f.push_replica("net", 1, u64::MAX / 4);
        f.push_replica("net", 4, u64::MAX / 4);
        let got: Vec<Admission> =
            (0..6).map(|i| f.offer("net", i).unwrap()).collect();
        assert_eq!(
            got,
            vec![
                Admission::Admitted { replica: 0 },
                Admission::Admitted { replica: 1 },
                Admission::Admitted { replica: 1 },
                Admission::Admitted { replica: 1 },
                Admission::Admitted { replica: 1 },
                Admission::Rejected,
            ]
        );
        let s = f.stats();
        assert_eq!(s.shards[0].rejected, 1, "charged to the preferred replica");
        assert_eq!(s.shards[1].rejected, 0);
    }

    #[test]
    fn batch_tier_is_shed_past_its_queue_share() {
        // Cap 4 → batch share max(1, 4/4) = 1; nothing ever completes, so
        // admission outcomes are purely the tiered-cap law.
        let mut f = SimFleet::new(&[SimServiceModel {
            service_ns: u64::MAX / 4,
            ..SimServiceModel::new("a", 1.0, 4, 1)
        }])
        .unwrap();
        assert_eq!(
            f.offer_prioritized("a", 0, Priority::Batch).unwrap(),
            Admission::Admitted { replica: 0 }
        );
        assert_eq!(f.offer_prioritized("a", 1, Priority::Batch).unwrap(), Admission::Shed);
        for t in 2..5 {
            assert_eq!(
                f.offer_prioritized("a", t, Priority::Interactive).unwrap(),
                Admission::Admitted { replica: 0 },
                "interactive rides the full cap"
            );
        }
        assert_eq!(
            f.offer_prioritized("a", 5, Priority::Interactive).unwrap(),
            Admission::Rejected
        );
        let ns = &f.network_stats()[0];
        assert_eq!((ns.offered, ns.admitted, ns.rejected, ns.shed), (6, 4, 1, 1));
        assert_eq!(ns.offered_tier, [4, 2]);
        assert_eq!(ns.rejected_tier, [1, 0]);
        assert_eq!(ns.shed_tier, [0, 1]);
        assert!((ns.overload_rate - 1.0 / 6.0).abs() < 1e-12, "shed is NOT overload");
        // Only the interactive rejection is charged to the replica row the
        // SLO tracker reads; the shed batch request is not.
        assert_eq!(f.stats().shards[0].rejected, 1);
    }

    #[test]
    fn wedged_replica_defers_dispatch_but_stats_stay_instant() {
        let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 8, 1)]).unwrap();
        assert!(f.wedge_replica("a", 0, 5_000_000));
        assert!(!f.wedge_replica("ghost", 0, 1), "unknown network is a no-op");
        assert!(!f.wedge_replica("a", 7, 1), "unknown ordinal is a no-op");
        f.offer("a", 0).unwrap();
        // The wedged worker admits but does not dispatch — and the stats
        // plane still answers instantly from the queue counters, exactly
        // the live stats()-stays-instant behavior under a stalled worker.
        let s = f.stats();
        assert_eq!(s.shards[0].queue_depth, 1);
        assert_eq!(s.shards[0].service.requests, 0);
        f.run_until(4_999_999);
        assert_eq!(f.network_stats()[0].completed, 0, "stalled through the wedge");
        f.drain();
        assert_eq!(f.network_stats()[0].completed, 1, "the backlog survives the stall");
        assert!((f.now_ms() - 6.0).abs() < 1e-9, "wake at 5 ms + 1 ms service");
    }

    #[test]
    fn dispatch_serves_mixed_backlog_in_wfq_order() {
        // Wedge the lone replica so a mixed backlog accumulates, then let
        // the serial (max_batch 1) drain reveal the pick order: weights
        // 3:1 over queues I=[2 reqs], B=[1 req] serve I, I, B.
        let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 8, 1)]).unwrap();
        assert!(f.wedge_replica("a", 0, 1_000_000));
        assert_eq!(
            f.offer_prioritized("a", 0, Priority::Batch).unwrap(),
            Admission::Admitted { replica: 0 }
        );
        for _ in 0..2 {
            assert_eq!(
                f.offer_prioritized("a", 0, Priority::Interactive).unwrap(),
                Admission::Admitted { replica: 0 }
            );
        }
        f.run_until(2_000_000);
        assert_eq!(f.network_stats()[0].completed_tier, [1, 0], "interactive first");
        f.run_until(3_000_000);
        assert_eq!(f.network_stats()[0].completed_tier, [2, 0]);
        f.drain();
        assert_eq!(f.network_stats()[0].completed_tier, [2, 1]);
        assert!((f.now_ms() - 4.0).abs() < 1e-9, "wake at 1 ms + 3 serial services");
    }

    #[test]
    fn unknown_network_is_a_usage_error() {
        let mut f = SimFleet::new(&two_net_models()).unwrap();
        assert!(f.offer("ghost", 0).is_err());
    }

    #[test]
    fn scale_down_drains_and_refuses_the_last_replica() {
        let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 4, 2)]).unwrap();
        // Load replica 0 so the highest-ordinal (1) is removed idle, then
        // the drain path: re-add, load IT, and remove while busy.
        f.offer("a", 0).unwrap();
        assert_eq!(f.replica_count("a"), 2);
        f.scale_down("a").unwrap();
        assert_eq!(f.replica_count("a"), 1);
        assert!(f.scale_down("a").is_err(), "last replica is protected");
        // Busy removal: replica 1 re-added, gets the next request (load
        // order), then drains on removal — its completion still lands.
        f.push_replica("a", 4, 1_000_000);
        f.offer("a", 100).unwrap();
        let before = f.stats().fleet.requests;
        f.scale_down("a").unwrap();
        assert_eq!(f.replica_count("a"), 1);
        f.drain();
        let ns = f.network_stats();
        assert_eq!(ns[0].completed, 2, "draining replica completed its backlog");
        assert!(f.stats().fleet.requests >= before);
    }

    #[test]
    fn fail_device_unroutes_at_once_but_drops_no_in_flight_request() {
        let models = vec![
            SimServiceModel::new("a", 1.0, 8, 2).on_platform("dev0", 0.1),
            SimServiceModel::new("b", 1.0, 8, 1).on_platform("dev1", 0.1),
        ];
        let mut f = SimFleet::new(&models).unwrap();
        f.set_contention_alpha(0.0);
        f.offer("a", 0).unwrap();
        f.offer("a", 0).unwrap();
        f.offer("b", 0).unwrap();
        // Both `a` replicas have a batch in service when the device dies.
        assert_eq!(f.fail_device("dev0"), 2);
        assert_eq!(f.replica_count("a"), 0, "dead device unrouted immediately");
        assert_eq!(f.replica_count("b"), 1, "the other device is untouched");
        f.drain();
        let ns = f.network_stats();
        assert_eq!(ns[0].network, "a");
        assert_eq!(ns[0].completed, 2, "in-flight work drained, never dropped");
        assert_eq!(ns[1].completed, 1);
        assert_eq!(f.fail_device("dev0"), 0, "nothing left on the device");
        assert_eq!(f.fail_device("ghost"), 0, "unknown devices are a no-op");
    }

    #[test]
    fn rebind_pays_the_outage_before_activating_on_the_new_device() {
        let models = vec![
            SimServiceModel::new("a", 1.0, 8, 1).on_platform("dev0", 0.2),
            SimServiceModel::new("b", 1.0, 8, 1).on_platform("dev1", 0.2),
        ];
        let mut f = SimFleet::new(&models).unwrap();
        f.set_contention_alpha(0.0);
        // Reprogram dev1 (currently b's) with a's bitstream: 2 fresh
        // replicas after a 5 ms outage.
        assert!(f.rebind_device("dev1", "ghost", 1, 5.0).is_err());
        assert_eq!(f.rebind_device("dev1", "a", 2, 5.0).unwrap(), 1);
        assert_eq!(f.replica_count("b"), 0, "evicted binding is gone at once");
        assert_eq!(f.replica_count("a"), 1, "no capacity during the outage");
        f.run_until(4_999_999);
        assert_eq!(f.replica_count("a"), 1);
        f.run_until(5_000_000);
        assert_eq!(f.replica_count("a"), 3, "outage over: fresh replicas up");
        // The fresh replicas serve and their ordinals extend a's sequence.
        f.offer("a", 5_000_000).unwrap();
        f.drain();
        assert_eq!(f.network_stats()[0].completed, 1);
    }

    #[test]
    fn telemetry_attached_fleet_assembles_complete_per_request_traces() {
        use crate::obs::{trace, Telemetry};
        let t = Arc::new(Telemetry::new());
        let model = SimServiceModel::new("a", 1.0, 8, 2).with_batching(4, 0.4);
        let mut f = SimFleet::new(&[model]).unwrap();
        f.set_telemetry(Arc::clone(&t));
        for i in 0..5u64 {
            assert!(matches!(f.offer("a", i).unwrap(), Admission::Admitted { .. }));
        }
        f.drain();
        // Spans landed in per-(network, replica) rings, not the hub — and
        // each ring's serialized timeline reassembles every admitted
        // request into exactly one complete trace.
        assert_eq!(t.ring_stats().len(), 2, "one ring per replica");
        let mut complete = 0u64;
        for (network, _replica, events) in t.ring_snapshots() {
            assert_eq!(network, "a");
            let asm = trace::assemble(&events);
            assert_eq!(
                (asm.orphaned, asm.incomplete, asm.double_counted),
                (0, 0, 0),
                "nothing orphaned or double-counted"
            );
            for rt in &asm.complete {
                assert_ne!(rt.trace, trace::UNTRACED);
                assert!(rt.total_ns >= rt.exec_ns);
            }
            complete += asm.complete.len() as u64;
        }
        assert_eq!(complete, 5, "every admitted request assembles exactly once");
    }

    #[test]
    fn drift_expectations_read_contention_off_the_actual_packing() {
        let models = vec![
            SimServiceModel::new("a", 1.0, 8, 2).with_batching(4, 0.4).on_platform("dev", 0.3),
            SimServiceModel::new("b", 0.5, 8, 1),
        ];
        let f = SimFleet::new(&models).unwrap();
        let exps = f.drift_expectations(2.07);
        assert_eq!(exps.len(), 2);
        let a = exps.iter().find(|e| e.network == "a").unwrap();
        assert!((a.contention_x - 0.3).abs() < 1e-9, "the OTHER replica's share");
        assert_eq!(a.service_ns, 1_000_000);
        assert_eq!(a.fill_ns, 400_000);
        assert!((a.alpha - 2.07).abs() < 1e-12);
        let b = exps.iter().find(|e| e.network == "b").unwrap();
        assert!(b.contention_x.abs() < 1e-12, "no platform, no contention");
    }

    #[test]
    fn simulate_trace_is_deterministic() {
        let scenario = Scenario::new(
            ScenarioShape::Burst,
            vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
            5_000.0,
            2_000.0,
            42,
        );
        let trace = scenario.arrivals();
        let run = |t: &Trace| {
            let mut f = SimFleet::new(&two_net_models()).unwrap();
            simulate_trace(&mut f, t, &mut [], &SimRunOptions::default()).unwrap()
        };
        let a = run(&trace);
        let b = run(&trace);
        assert_eq!(a.events, b.events);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.networks, b.networks);
        assert!(a.offered > 0);
        assert_eq!(a.completed, a.admitted, "runner drains every admitted request");
    }

    #[test]
    fn batched_trace_is_deterministic_and_faster_than_serial() {
        let scenario = Scenario::new(
            ScenarioShape::Steady,
            vec![("a".to_string(), 1.0)],
            3_000.0,
            1_000.0,
            7,
        );
        let trace = scenario.arrivals();
        let run = |max_batch: usize| {
            let mut f = SimFleet::new(&[SimServiceModel::new("a", 1.0, 64, 2)
                .with_batching(max_batch, 0.5)])
            .unwrap();
            simulate_trace(&mut f, &trace, &mut [], &SimRunOptions::default()).unwrap()
        };
        let serial = run(1);
        let batched = run(8);
        let batched2 = run(8);
        assert_eq!(batched.events, batched2.events, "batched runs replay identically");
        assert_eq!(batched.networks, batched2.networks);
        // 3000 qps offered vs 1000/s serial capacity per replica: the
        // serial fleet lags far behind; amortized batches keep up better,
        // so the batched run finishes its backlog sooner.
        assert!(
            batched.virtual_ms < serial.virtual_ms,
            "coalescing must raise throughput: {} vs {} ms",
            batched.virtual_ms,
            serial.virtual_ms
        );
        assert_eq!(batched.completed, batched.admitted);
    }
}
