//! SLO policy search: sweep `SloPolicy` grids through the what-if
//! simulator and report the Pareto front.
//!
//! PR 3 hand-picked the autoscaler's [`SloPolicy`] knobs; this module turns
//! them into a searched design space, the same move the paper makes for
//! block configurations (and CNN2Gate, arXiv:2004.04641, makes for whole
//! accelerator designs): when evaluation is cheap — a controlled traffic
//! run costs milliseconds of wall time on the virtual clock — exhaustive
//! sweeps beat intuition. [`search`] replays ONE fixed scenario trace
//! against every policy in a [`PolicyGrid`] (queue-idle threshold, overload
//! target, p95 ratio, hysteresis window) through the *production*
//! controller path (`whatif::run_controlled` →
//! [`crate::fleetplan::Autoscaler::step_target`]), scores each run on
//!
//! * **sustained QPS** — completions per virtual second (a policy that
//!   falls behind drags its drain tail and scores lower),
//! * **p95 latency** — worst per-network all-time virtual p95,
//! * **reject rate** — bounded-admission turn-aways over offers,
//! * **replica-seconds** — the trajectory's ∫ replicas dt cost,
//!
//! and marks the policies no other policy beats on every axis
//! ([`pareto_front`]). [`search_chaos`] runs the same sweep with a seeded
//! [`ChaosPlan`] injected into every run — replica kills, wedged workers,
//! device outages, burst storms — and scores two extra axes: worst
//! **recovery-to-SLO** per fault and batch/interactive **tier fairness**,
//! so the front trades resilience against fleet cost, not just latency.
//! Everything is a pure function of
//! `(scenario, seed, registry, grid, options)`, so the report JSON is
//! byte-identical across runs and CI archives and diffs it like
//! `SIM_capacity.json`. Surfaces: `convkit policysearch`,
//! [`crate::report::pareto_table`].

use super::chaos::{run_planned_chaos, ChaosPlan};
use super::whatif::{
    autosize_scenario, json_escape, plan_rows, run_controlled, WhatIfOptions,
};
use super::workload::Scenario;
use crate::fleetplan::{
    select_platform_or_spill, NetworkDemand, ScaleAction, SloPolicy, SpillPlan,
};
use crate::models::ModelRegistry;
use crate::platform::Platform;
use crate::simulate::TrajectoryPoint;
use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// The swept `SloPolicy` knob grid (cartesian product; row order is the
/// nested iteration order: overload → ratio → idle-queue → window).
#[derive(Debug, Clone)]
pub struct PolicyGrid {
    /// Tolerated overload rates ([`SloPolicy::overload_target`]).
    pub overload_targets: Vec<f64>,
    /// Latency-aware p95 ratios ([`SloPolicy::p95_ratio`]).
    pub p95_ratios: Vec<f64>,
    /// Idle queue-utilization thresholds ([`SloPolicy::idle_queue_util`]).
    pub idle_queue_utils: Vec<f64>,
    /// Hysteresis windows in snapshots ([`SloPolicy::window`]).
    pub windows: Vec<usize>,
}

impl Default for PolicyGrid {
    /// A 2×2×2×2 grid bracketing the PR 3 hand-picked defaults.
    fn default() -> Self {
        PolicyGrid {
            overload_targets: vec![0.005, 0.02],
            p95_ratios: vec![2.0, 6.0],
            idle_queue_utils: vec![0.05, 0.25],
            windows: vec![2, 4],
        }
    }
}

impl PolicyGrid {
    /// Grid size (number of policies swept).
    pub fn len(&self) -> usize {
        self.overload_targets.len()
            * self.p95_ratios.len()
            * self.idle_queue_utils.len()
            * self.windows.len()
    }

    /// True when any axis is empty (nothing to sweep).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the grid over `base` (which contributes the absolute
    /// p95 fallback target), in deterministic row order.
    pub fn policies(&self, base: &SloPolicy) -> Vec<SloPolicy> {
        let mut out = Vec::with_capacity(self.len());
        for &overload_target in &self.overload_targets {
            for &p95_ratio in &self.p95_ratios {
                for &idle_queue_util in &self.idle_queue_utils {
                    for &window in &self.windows {
                        out.push(SloPolicy {
                            p95_target_ms: base.p95_target_ms,
                            p95_ratio,
                            overload_target,
                            idle_queue_util,
                            window,
                        });
                    }
                }
            }
        }
        out
    }
}

/// One policy's scored run.
#[derive(Debug, Clone)]
pub struct PolicyScore {
    /// The policy that produced this row.
    pub policy: SloPolicy,
    /// Completions per virtual second over the whole run (drain included).
    pub sustained_qps: f64,
    /// Worst per-network all-time p95 completion latency (virtual ms).
    pub p95_ms: f64,
    /// Rejected / offered across all networks.
    pub reject_rate: f64,
    /// ∫ routable replicas dt over the run (virtual replica-seconds) — the
    /// fleet-cost axis.
    pub replica_seconds: f64,
    /// Scale-up decisions taken.
    pub scale_ups: usize,
    /// Scale-down decisions taken.
    pub scale_downs: usize,
    /// Worst recovery-to-SLO over the run's injected faults (virtual ms) —
    /// 0 for a plain (fault-free) search, where the axis is inert.
    pub recovery_ms: f64,
    /// Batch-tier completion rate relative to interactive, in `[0, 1]`
    /// (`ChaosReport::tier_fairness`) — 1 for a plain search, where every
    /// request is interactive and the axis is inert.
    pub tier_fairness: f64,
    /// On the Pareto front (no other row is at least as good on every
    /// objective and strictly better on one).
    pub pareto: bool,
}

/// The full sweep outcome for one scenario.
#[derive(Debug, Clone)]
pub struct PolicySearchReport {
    /// Scenario name.
    pub scenario: String,
    /// Scenario seed.
    pub seed: u64,
    /// Selected primary platform.
    pub platform: String,
    /// Spill platform, when one device could not hold the floors.
    pub spill_platform: Option<String>,
    /// Utilization cap used for planning.
    pub cap: f64,
    /// Mean offered load of the swept trace (requests per virtual second).
    pub qps: f64,
    /// Arrivals in the swept trace (every policy sees the same one).
    pub arrivals: u64,
    /// One scored row per policy, in grid order.
    pub rows: Vec<PolicyScore>,
}

impl PolicySearchReport {
    /// The Pareto-front rows, in grid order.
    pub fn front(&self) -> Vec<&PolicyScore> {
        self.rows.iter().filter(|r| r.pareto).collect()
    }

    /// Deterministic hand-rolled JSON (no serde offline), byte-identical
    /// for a fixed `(scenario, seed, registry, grid, options)` — archived
    /// and diffed by CI alongside `SIM_capacity.json`.
    ///
    /// Schema (top-level key `policysearch`):
    ///
    /// ```json
    /// {"policysearch": {
    ///   "scenario": "burst", "seed": 42, "platform": "KV260",
    ///   "spill_platform": null, "cap": 0.800, "qps": 1234.5,
    ///   "arrivals": 20000, "grid": 16, "front": [0, 3],
    ///   "rows": [
    ///     {"overload_target": 0.0050, "p95_ratio": 2.00,
    ///      "idle_queue_util": 0.050, "window": 2,
    ///      "sustained_qps": 1200.0, "p95_ms": 0.012345,
    ///      "reject_rate": 0.001000, "replica_seconds": 12.345,
    ///      "scale_ups": 3, "scale_downs": 2,
    ///      "recovery_ms": 0.000, "tier_fairness": 1.0000,
    ///      "pareto": true}]}}
    /// ```
    ///
    /// `recovery_ms` and `tier_fairness` are live axes only for
    /// [`search_chaos`] sweeps; plain [`search`] rows pin them to their
    /// inert values (0 / 1) so both report kinds share one schema.
    ///
    /// `front` lists the indices of `rows` on the Pareto front; row order
    /// is the grid's nested iteration order.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n  \"policysearch\": {\n");
        out.push_str(&format!("    \"scenario\": \"{}\",\n", json_escape(&self.scenario)));
        out.push_str(&format!("    \"seed\": {},\n", self.seed));
        out.push_str(&format!("    \"platform\": \"{}\",\n", json_escape(&self.platform)));
        match &self.spill_platform {
            Some(p) => {
                out.push_str(&format!("    \"spill_platform\": \"{}\",\n", json_escape(p)))
            }
            None => out.push_str("    \"spill_platform\": null,\n"),
        }
        out.push_str(&format!("    \"cap\": {:.3},\n", self.cap));
        out.push_str(&format!("    \"qps\": {:.1},\n", self.qps));
        out.push_str(&format!("    \"arrivals\": {},\n", self.arrivals));
        out.push_str(&format!("    \"grid\": {},\n", self.rows.len()));
        let front: Vec<String> = self
            .rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.pareto)
            .map(|(i, _)| i.to_string())
            .collect();
        out.push_str(&format!("    \"front\": [{}],\n", front.join(", ")));
        out.push_str("    \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"overload_target\": {:.4}, \"p95_ratio\": {:.2}, \
                 \"idle_queue_util\": {:.3}, \"window\": {}, \
                 \"sustained_qps\": {:.1}, \"p95_ms\": {:.6}, \
                 \"reject_rate\": {:.6}, \"replica_seconds\": {:.3}, \
                 \"scale_ups\": {}, \"scale_downs\": {}, \
                 \"recovery_ms\": {:.3}, \"tier_fairness\": {:.4}, \
                 \"pareto\": {}}}{}\n",
                r.policy.overload_target,
                r.policy.p95_ratio,
                r.policy.idle_queue_util,
                r.policy.window,
                r.sustained_qps,
                r.p95_ms,
                r.reject_rate,
                r.replica_seconds,
                r.scale_ups,
                r.scale_downs,
                r.recovery_ms,
                r.tier_fairness,
                r.pareto,
                if i + 1 == self.rows.len() { "" } else { "," }
            ));
        }
        out.push_str("    ]\n  }\n}\n");
        out
    }
}

/// Pareto-front flags for a set of points under *minimization* of every
/// coordinate: `true` where no other point is ≤ on every coordinate and
/// strictly < on at least one. Duplicated points all stay on the front.
///
/// ```
/// use convkit::simulate::policysearch::pareto_front;
/// let pts = vec![
///     vec![0.0, 1.0], // best on axis 0
///     vec![1.0, 0.0], // best on axis 1
///     vec![1.0, 1.0], // dominated by [0.5, 0.5]
///     vec![0.5, 0.5], // a trade-off nobody beats
/// ];
/// assert_eq!(pareto_front(&pts), vec![true, true, false, true]);
/// ```
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<bool> {
    let dominates = |a: &[f64], b: &[f64]| {
        a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
    };
    points
        .iter()
        .map(|p| !points.iter().any(|q| dominates(q, p)))
        .collect()
}

/// ∫ routable replicas dt (virtual seconds) over a replica trajectory that
/// records the initial counts plus every change point.
fn replica_seconds(trajectory: &[TrajectoryPoint], end_ms: f64) -> f64 {
    let mut per: BTreeMap<&str, Vec<(f64, usize)>> = BTreeMap::new();
    for p in trajectory {
        per.entry(p.network.as_str()).or_default().push((p.t_ms, p.replicas));
    }
    let mut total = 0.0;
    for pts in per.values() {
        for (i, (t, n)) in pts.iter().enumerate() {
            let t_next = pts.get(i + 1).map(|(t2, _)| *t2).unwrap_or(end_ms).max(*t);
            total += *n as f64 * (t_next - t) / 1e3;
        }
    }
    total
}

/// Sweep `grid` over one auto-sized scenario: plan (with spill fallback),
/// generate ONE trace, replay it through the production controller once per
/// policy, score, and mark the Pareto front. `opts.policy` supplies the
/// absolute p95 fallback target; its swept knobs are overridden per row.
pub fn search(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    platforms: &[Platform],
    scenario: &Scenario,
    grid: &PolicyGrid,
    opts: &WhatIfOptions,
) -> Result<PolicySearchReport> {
    if grid.is_empty() {
        return Err(Error::InvalidConfig(
            "policy grid is empty: every axis needs at least one value".into(),
        ));
    }
    let spill = select_platform_or_spill(demands, registry, platforms, opts.cap)?;
    let sc = autosize_scenario(scenario, demands, &spill, opts)?;
    let trace = sc.arrivals();
    if trace.is_empty() {
        return Err(Error::InvalidConfig("policy search trace has no arrivals".into()));
    }

    let mut rows = Vec::with_capacity(grid.len());
    for policy in grid.policies(&opts.policy) {
        let (run, _) = run_controlled(&spill, &trace, &policy, opts)?;
        let virtual_s = (run.virtual_ms / 1e3).max(1e-9);
        let p95_ms = run.networks.iter().map(|n| n.p95_ms).fold(0.0f64, f64::max);
        let reject_rate = if run.offered == 0 {
            0.0
        } else {
            run.rejected as f64 / run.offered as f64
        };
        let scale_ups =
            run.decisions.iter().filter(|d| d.action == ScaleAction::Up).count();
        // Explicit Down filter: `len - ups` would miscount rebinds as
        // scale-downs now that ScaleAction has a third variant.
        let scale_downs =
            run.decisions.iter().filter(|d| d.action == ScaleAction::Down).count();
        rows.push(PolicyScore {
            policy,
            sustained_qps: run.completed as f64 / virtual_s,
            p95_ms,
            reject_rate,
            replica_seconds: replica_seconds(&run.trajectory, run.virtual_ms),
            scale_ups,
            scale_downs,
            recovery_ms: 0.0,
            tier_fairness: 1.0,
            pareto: false,
        });
    }
    mark_front(&mut rows);
    Ok(assemble_report(&spill, &sc, trace.len(), opts.cap, rows))
}

/// Sweep `grid` as [`search`] does, but inject `plan`'s fault schedule into
/// every run ([`run_planned_chaos`]): each policy faces the identical seeded
/// chaos — replica kills, wedged workers, device outages and rebinds, burst
/// storms — on the identical trace, and two extra objectives go live:
/// worst recovery-to-SLO across the injected faults and batch/interactive
/// tier fairness. The report stays byte-deterministic, so CI can archive a
/// resilience front next to the plain one.
pub fn search_chaos(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    platforms: &[Platform],
    scenario: &Scenario,
    grid: &PolicyGrid,
    opts: &WhatIfOptions,
    plan: &ChaosPlan,
) -> Result<PolicySearchReport> {
    if grid.is_empty() {
        return Err(Error::InvalidConfig(
            "policy grid is empty: every axis needs at least one value".into(),
        ));
    }
    let spill = select_platform_or_spill(demands, registry, platforms, opts.cap)?;
    let sc = autosize_scenario(scenario, demands, &spill, opts)?;
    let trace = sc.arrivals();
    if trace.is_empty() {
        return Err(Error::InvalidConfig("policy search trace has no arrivals".into()));
    }

    let mut rows = Vec::with_capacity(grid.len());
    for policy in grid.policies(&opts.policy) {
        let report = run_planned_chaos(&spill, &trace, &policy, opts, plan)?;
        let virtual_s = (report.virtual_ms / 1e3).max(1e-9);
        let p95_ms = report.networks.iter().map(|n| n.p95_ms).fold(0.0f64, f64::max);
        let reject_rate = if report.offered == 0 {
            0.0
        } else {
            report.rejected as f64 / report.offered as f64
        };
        rows.push(PolicyScore {
            policy,
            sustained_qps: report.completed as f64 / virtual_s,
            p95_ms,
            reject_rate,
            replica_seconds: replica_seconds(&report.trajectory, report.virtual_ms),
            scale_ups: report.scale_ups,
            scale_downs: report.scale_downs,
            recovery_ms: report.worst_recovery_ms(),
            tier_fairness: report.tier_fairness(),
            pareto: false,
        });
    }
    mark_front(&mut rows);
    Ok(assemble_report(&spill, &sc, trace.len(), opts.cap, rows))
}

/// Flag the Pareto front over the six scored objectives, all as
/// minimizations: −QPS, p95, reject rate, replica-seconds, recovery time,
/// 1 − fairness. The chaos-only axes are inert constants in plain-search
/// rows (0 and 1 respectively), so they never decide dominance there.
fn mark_front(rows: &mut [PolicyScore]) {
    let points: Vec<Vec<f64>> = rows
        .iter()
        .map(|r| {
            vec![
                -r.sustained_qps,
                r.p95_ms,
                r.reject_rate,
                r.replica_seconds,
                r.recovery_ms,
                1.0 - r.tier_fairness,
            ]
        })
        .collect();
    for (row, flag) in rows.iter_mut().zip(pareto_front(&points)) {
        row.pareto = flag;
    }
}

fn assemble_report(
    spill: &SpillPlan,
    sc: &Scenario,
    arrivals: usize,
    cap: f64,
    rows: Vec<PolicyScore>,
) -> PolicySearchReport {
    let hosts = plan_rows(spill);
    PolicySearchReport {
        scenario: sc.shape.name().to_string(),
        seed: sc.seed,
        platform: hosts[0].1.clone(),
        spill_platform: hosts.get(1).map(|(_, h)| h.clone()),
        cap,
        qps: sc.qps,
        arrivals: arrivals as u64,
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_brackets_the_defaults() {
        let g = PolicyGrid::default();
        assert_eq!(g.len(), 16);
        assert!(!g.is_empty());
        let base = SloPolicy::default();
        let policies = g.policies(&base);
        assert_eq!(policies.len(), 16);
        // Row order is the nested iteration order: the LAST axis varies
        // fastest (the determinism the JSON archive relies on).
        assert_eq!(policies[0].window, 2);
        assert_eq!(policies[1].window, 4);
        assert_eq!(policies[0].overload_target, policies[1].overload_target);
        // The absolute fallback rides along unchanged.
        assert!(policies.iter().all(|p| p.p95_target_ms == base.p95_target_ms));
    }

    #[test]
    fn pareto_front_keeps_trade_offs_and_drops_dominated_rows() {
        let pts = vec![
            vec![1.0, 5.0, 0.0],
            vec![2.0, 1.0, 0.0],
            vec![2.0, 5.0, 0.0], // dominated by both
            vec![1.0, 5.0, 0.0], // duplicate of row 0: stays
        ];
        assert_eq!(pareto_front(&pts), vec![true, true, false, true]);
    }

    #[test]
    fn replica_seconds_integrates_the_step_function() {
        let traj = vec![
            TrajectoryPoint { t_ms: 0.0, network: "a".into(), replicas: 1 },
            TrajectoryPoint { t_ms: 1000.0, network: "a".into(), replicas: 3 },
            TrajectoryPoint { t_ms: 0.0, network: "b".into(), replicas: 2 },
        ];
        // a: 1×1s + 3×1s = 4; b: 2×2s = 4.
        let got = replica_seconds(&traj, 2000.0);
        assert!((got - 8.0).abs() < 1e-9, "{got}");
    }

    #[test]
    fn mark_front_scores_recovery_and_fairness_as_live_axes() {
        let row = |recovery_ms: f64, tier_fairness: f64| PolicyScore {
            policy: SloPolicy::default(),
            sustained_qps: 100.0,
            p95_ms: 1.0,
            reject_rate: 0.0,
            replica_seconds: 10.0,
            scale_ups: 0,
            scale_downs: 0,
            recovery_ms,
            tier_fairness,
            pareto: false,
        };
        // Identical on the four plain axes; chaos axes decide dominance.
        let mut rows = vec![row(5.0, 1.0), row(50.0, 1.0), row(5.0, 0.5)];
        mark_front(&mut rows);
        let flags: Vec<bool> = rows.iter().map(|r| r.pareto).collect();
        // Row 1 recovers slower at equal fairness → dominated by row 0;
        // row 2 is less fair at equal recovery → also dominated by row 0.
        assert_eq!(flags, vec![true, false, false]);
    }

    #[test]
    fn empty_grid_is_rejected() {
        let g = PolicyGrid { windows: vec![], ..PolicyGrid::default() };
        assert!(g.is_empty());
        assert_eq!(g.len(), 0);
    }
}
