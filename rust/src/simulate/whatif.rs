//! The what-if capacity explorer: FleetPlan × Platform × policy → report.
//!
//! [`explore`] answers the question the paper's fast models exist for:
//! *"what happens to this fleet under that traffic, on which device?"* —
//! without synthesis, executors, or wall-clock waiting. It selects a
//! platform with the spill-aware planner
//! ([`crate::fleetplan::select_platform_or_spill`]), prices each network's
//! virtual service rate from the plan's model-predicted latency, replays a
//! seeded [`Scenario`] (or a recorded trace, via [`explore_replay`])
//! through the discrete-event engine with the *production* autoscaler in
//! the loop, and then bisects for the maximum sustainable QPS — the
//! offered load the fully-planned fleet can absorb while keeping the
//! admission overload rate under a target.
//!
//! The resulting [`CapacityReport`] is a pure function of its inputs
//! (byte-identical JSON for the same seed + scenario + registry), so CI can
//! archive it next to the perf baseline and diff capacity the way it diffs
//! latency.

use super::engine::{
    simulate_trace, SimFleet, SimRunOptions, SimServiceModel, TrajectoryPoint,
    DEFAULT_CONTENTION_ALPHA,
};
use super::workload::{Scenario, Trace};
use crate::coordinator::ShardSpec;
use crate::fleetplan::{
    plan_pool, select_platform_or_spill, Autoscaler, DevicePool, FleetPlan, NetworkDemand,
    PoolPlan, ReconfigPolicy, ScaleAction, SloPolicy, SpillPlan,
};
use crate::models::ModelRegistry;
use crate::obs::{DriftMonitor, DriftReport, HistogramRow, Telemetry};
use crate::platform::Platform;
use crate::util::error::{Error, Result};
use std::sync::Arc;

/// Knobs for a what-if exploration.
#[derive(Debug, Clone)]
pub struct WhatIfOptions {
    /// Utilization cap plans are solved under (the paper's 0.8).
    pub cap: f64,
    /// Per-replica bounded-admission cap inside the simulation.
    pub queue_cap: usize,
    /// Requests coalesced per virtual service event (the live
    /// `ShardSpec::batch_size` default; 1 = the PR 4
    /// one-request-one-service-time model).
    pub max_batch: usize,
    /// Coalescing window opened when a request reaches an idle replica (ms
    /// of virtual time). The live worker waits
    /// [`crate::coordinator::service::BATCH_WINDOW`] (100 µs) of *wall*
    /// time — tuned for software service times; against µs-scale
    /// model-predicted hardware latencies that constant would dominate
    /// every tail, so the default is 0: batches then form exactly when a
    /// backlog exists, which is the regime the live window exists to reach.
    pub coalesce_window_ms: f64,
    /// Device-contention slope: co-located replicas stretch each other's
    /// service by `1 + alpha × (co-located utilization share excluding
    /// self)`. 0 disables contention.
    pub contention_alpha: f64,
    /// SLO policy handed to the (real) autoscaler.
    pub policy: SloPolicy,
    /// Virtual controller cadence (ms).
    pub control_interval_ms: f64,
    /// Calm ticks appended after the trace drains.
    pub cooldown_ticks: usize,
    /// Judge p95 against model-predicted latency × ratio (the latency-aware
    /// SLO) instead of the absolute constant.
    pub latency_slo: bool,
    /// Overload rate the max-QPS bisection must stay under.
    pub sustain_overload: f64,
    /// Arrivals per bisection probe run.
    pub probe_arrivals: u64,
    /// When the scenario's duration is 0 (auto), size it so at least this
    /// many arrivals are generated — the ≥1M-virtual-event knob.
    pub min_arrivals: u64,
    /// Telemetry plane attached to the MAIN controlled run (bisection probe
    /// runs stay silent): the fleet emits spans/stages on the virtual clock,
    /// the controllers journal their decisions into it, and the report
    /// embeds its per-stage latency breakdown.
    pub obs: Option<Arc<Telemetry>>,
}

impl Default for WhatIfOptions {
    fn default() -> Self {
        WhatIfOptions {
            cap: 0.8,
            queue_cap: 64,
            max_batch: 8,
            coalesce_window_ms: 0.0,
            contention_alpha: DEFAULT_CONTENTION_ALPHA,
            policy: SloPolicy::default(),
            control_interval_ms: 50.0,
            cooldown_ticks: 6,
            latency_slo: true,
            sustain_overload: 0.01,
            probe_arrivals: 4_000,
            min_arrivals: 1_000_000,
            obs: None,
        }
    }
}

/// One network's row in the capacity report.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkCapacity {
    /// Network name.
    pub network: String,
    /// Device hosting this network's replicas.
    pub platform: String,
    /// Model-predicted service latency per replica (ms).
    pub predicted_ms: f64,
    /// Replica ceiling the plan solved for this device.
    pub planned_replicas: u64,
    /// Replicas the simulation started with (the plan floors).
    pub start_replicas: u64,
    /// Highest routable replica count seen during the run.
    pub peak_replicas: usize,
    /// Routable replicas when the run ended.
    pub final_replicas: usize,
    /// Requests offered.
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Requests rejected at admission (every replica at cap).
    pub rejected: u64,
    /// rejected / offered.
    pub overload_rate: f64,
    /// Mean virtual completion latency (ms).
    pub mean_ms: f64,
    /// Simulated p95 latency (ms) — the model-predicted tail under this
    /// traffic.
    pub p95_ms: f64,
}

/// The full what-if outcome for one scenario.
#[derive(Debug, Clone)]
pub struct CapacityReport {
    /// Scenario name (`replay` for recorded traces).
    pub scenario: String,
    /// Scenario seed.
    pub seed: u64,
    /// Selected primary platform.
    pub platform: String,
    /// Spill platform, when one device could not hold the floors.
    pub spill_platform: Option<String>,
    /// Utilization cap used for planning.
    pub cap: f64,
    /// Mean offered load of the main run (requests per virtual second).
    pub qps: f64,
    /// Virtual events processed in the main run.
    pub events: u64,
    /// Virtual end time of the main run (ms).
    pub virtual_ms: f64,
    /// Max offered QPS the fully-planned fleet sustains with admission
    /// overload ≤ the target (bisected over steady probe runs).
    pub max_sustainable_qps: f64,
    /// Per-network rows (sorted by name).
    pub networks: Vec<NetworkCapacity>,
    /// Replica trajectory of the main run.
    pub trajectory: Vec<TrajectoryPoint>,
    /// Controller decisions, rendered with their virtual timestamps.
    pub decisions: Vec<String>,
    /// Scale-up count.
    pub scale_ups: usize,
    /// Scale-down count.
    pub scale_downs: usize,
    /// Per-stage latency breakdown from the attached telemetry plane
    /// ([`WhatIfOptions::obs`]); empty when no plane was attached.
    pub stages: Vec<HistogramRow>,
    /// Model-drift scorecard from the main run: every network's fitted
    /// latency/fill/contention model scored against the batches the
    /// telemetry plane recorded. `None` when no plane was attached.
    /// Deliberately NOT serialized by [`CapacityReport::to_json`] — the
    /// pinned `SIM_capacity.json` schema stays byte-stable; callers write
    /// it as its own `DRIFT_report.json` artifact via
    /// [`DriftReport::to_json`].
    pub drift: Option<DriftReport>,
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl CapacityReport {
    /// Deterministic hand-rolled JSON (no serde offline): top-level key
    /// `simulate`, diffable by `scripts/bench_diff.py --simulate`. This is
    /// the `SIM_capacity.json` artifact the CI bench job archives.
    ///
    /// Schema:
    ///
    /// ```json
    /// {"simulate": {
    ///   "scenario": "burst", "seed": 42, "platform": "KV260",
    ///   "spill_platform": null, "cap": 0.800, "qps": 1234.5,
    ///   "events": 100000, "virtual_ms": 123.456,
    ///   "max_sustainable_qps": 2000.0, "scale_ups": 3, "scale_downs": 2,
    ///   "networks": [
    ///     {"network": "tiny_q8", "platform": "KV260", "predicted_ms": 0.004,
    ///      "planned_replicas": 13, "start_replicas": 1, "peak_replicas": 3,
    ///      "final_replicas": 1, "offered": 1000, "admitted": 990,
    ///      "rejected": 10, "overload_rate": 0.01, "mean_ms": 0.005,
    ///      "p95_ms": 0.009}],
    ///   "trajectory": [{"t_ms": 0.0, "network": "tiny_q8", "replicas": 1}],
    ///   "decisions": ["t=+50.000ms scale-up tiny_q8 1→2: ..."],
    ///   "stages": [
    ///     {"stage": "obs_stage_exec_ns", "count": 990, "mean_ns": 4100.000,
    ///      "p50_ns": 4063, "p95_ns": 4575, "max_ns": 4501}]}}
    /// ```
    ///
    /// `networks` rows are sorted by name; `trajectory` records the initial
    /// replica counts plus every change point; `decisions` renders each
    /// controller step with its virtual timestamp; `stages` is the per-stage
    /// latency breakdown (empty without [`WhatIfOptions::obs`], diffed by
    /// `bench_diff.py --obs` against the full `OBS_snapshot.json`).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n  \"simulate\": {\n");
        out.push_str(&format!("    \"scenario\": \"{}\",\n", json_escape(&self.scenario)));
        out.push_str(&format!("    \"seed\": {},\n", self.seed));
        out.push_str(&format!("    \"platform\": \"{}\",\n", json_escape(&self.platform)));
        match &self.spill_platform {
            Some(p) => {
                out.push_str(&format!("    \"spill_platform\": \"{}\",\n", json_escape(p)))
            }
            None => out.push_str("    \"spill_platform\": null,\n"),
        }
        out.push_str(&format!("    \"cap\": {:.3},\n", self.cap));
        out.push_str(&format!("    \"qps\": {:.1},\n", self.qps));
        out.push_str(&format!("    \"events\": {},\n", self.events));
        out.push_str(&format!("    \"virtual_ms\": {:.3},\n", self.virtual_ms));
        out.push_str(&format!(
            "    \"max_sustainable_qps\": {:.1},\n",
            self.max_sustainable_qps
        ));
        out.push_str(&format!("    \"scale_ups\": {},\n", self.scale_ups));
        out.push_str(&format!("    \"scale_downs\": {},\n", self.scale_downs));
        out.push_str("    \"networks\": [\n");
        for (i, n) in self.networks.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"network\": \"{}\", \"platform\": \"{}\", \"predicted_ms\": {:.6}, \
                 \"planned_replicas\": {}, \"start_replicas\": {}, \"peak_replicas\": {}, \
                 \"final_replicas\": {}, \"offered\": {}, \"admitted\": {}, \"rejected\": {}, \
                 \"overload_rate\": {:.6}, \"mean_ms\": {:.6}, \"p95_ms\": {:.6}}}{}\n",
                json_escape(&n.network),
                json_escape(&n.platform),
                n.predicted_ms,
                n.planned_replicas,
                n.start_replicas,
                n.peak_replicas,
                n.final_replicas,
                n.offered,
                n.admitted,
                n.rejected,
                n.overload_rate,
                n.mean_ms,
                n.p95_ms,
                if i + 1 == self.networks.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"trajectory\": [\n");
        for (i, p) in self.trajectory.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"t_ms\": {:.3}, \"network\": \"{}\", \"replicas\": {}}}{}\n",
                p.t_ms,
                json_escape(&p.network),
                p.replicas,
                if i + 1 == self.trajectory.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"decisions\": [\n");
        for (i, d) in self.decisions.iter().enumerate() {
            out.push_str(&format!(
                "      \"{}\"{}\n",
                json_escape(d),
                if i + 1 == self.decisions.len() { "" } else { "," }
            ));
        }
        out.push_str("    ],\n    \"stages\": [\n");
        for (i, s) in self.stages.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"stage\": \"{}\", \"count\": {}, \"mean_ns\": {:.3}, \
                 \"p50_ns\": {}, \"p95_ns\": {}, \"max_ns\": {}}}{}\n",
                s.name,
                s.count,
                s.mean_ns,
                s.p50_ns,
                s.p95_ns,
                s.max_ns,
                if i + 1 == self.stages.len() { "" } else { "," }
            ));
        }
        out.push_str("    ]\n  }\n}\n");
        out
    }
}

/// `(plan, hosting platform name)` rows across a spill split.
pub(crate) fn plan_rows(spill: &SpillPlan) -> Vec<(&FleetPlan, String)> {
    let mut out = vec![(&spill.primary, spill.primary.platform.name.to_string())];
    if let Some(s) = &spill.spill {
        out.push((s, s.platform.name.to_string()));
    }
    out
}

/// `(plan, hosting device name)` rows across a pool plan. Device names are
/// the engine's contention groups, so a mixed pool gets per-device
/// contention for free; devices the planner left empty are skipped. For the
/// 2-device degenerate pool these rows are exactly [`plan_rows`]'s
/// ([`DevicePool::pair`] names devices after their platforms).
pub(crate) fn pool_rows(pool_plan: &PoolPlan) -> Vec<(&FleetPlan, String)> {
    pool_plan
        .devices
        .iter()
        .filter(|d| !d.plan.networks.is_empty())
        .map(|d| (&d.plan, d.device.clone()))
        .collect()
}

/// Weight fraction of each network in the mix. Non-positive weights are
/// substituted with 1.0 — the SAME rule [`Scenario::arrivals`] applies when
/// generating traffic — so capacity math and workload generation always
/// agree on who gets how much.
fn mix_fraction(mix: &[(String, f64)], network: &str) -> f64 {
    let weight = |w: f64| if w > 0.0 { w } else { 1.0 };
    let total: f64 = mix.iter().map(|(_, w)| weight(*w)).sum();
    if total <= 0.0 {
        return 0.0;
    }
    mix.iter()
        .find(|(n, _)| n == network)
        .map(|(_, w)| weight(*w) / total)
        .unwrap_or(0.0)
}

/// Closed-form aggregate capacity (requests/s) of `replicas(row)` replicas
/// per network under the mix: the bottleneck network saturates first. Uses
/// the *amortized* per-replica rate at `opts.max_batch` (fill paid once per
/// batch) and ignores contention, so it upper-bounds what the simulation
/// can actually sustain — exactly what the bisection needs for its ceiling.
pub(crate) fn capacity_qps<F>(
    rows: &[(&FleetPlan, String)],
    mix: &[(String, f64)],
    opts: &WhatIfOptions,
    replicas: F,
) -> f64
where
    F: Fn(&crate::fleetplan::NetworkPlan) -> u64,
{
    let b = opts.max_batch.max(1) as f64;
    let mut qps = f64::INFINITY;
    for (plan, _) in rows {
        for row in &plan.networks {
            let f = mix_fraction(mix, &row.network);
            if f <= 0.0 {
                continue;
            }
            let fill = row.fill_ms.clamp(0.0, row.predicted_ms);
            let per_item_ms = (fill + (row.predicted_ms - fill) * b) / b;
            let service_s = (per_item_ms / 1e3).max(1e-12);
            let rate = replicas(row) as f64 / service_s;
            qps = qps.min(rate / f);
        }
    }
    if qps.is_finite() {
        qps
    } else {
        0.0
    }
}

/// Simulated service models at a chosen replica count per plan row: service
/// rate, batch curve and device share all from the plan's fitted-model
/// predictions; batching and contention knobs from the options.
pub(crate) fn service_models<F>(
    rows: &[(&FleetPlan, String)],
    opts: &WhatIfOptions,
    replicas: F,
) -> Vec<SimServiceModel>
where
    F: Fn(&crate::fleetplan::NetworkPlan) -> u64,
{
    let mut models = Vec::new();
    for (plan, host) in rows {
        for row in &plan.networks {
            models.push(
                SimServiceModel::new(
                    &row.network,
                    row.predicted_ms,
                    opts.queue_cap,
                    replicas(row) as usize,
                )
                .with_batching(opts.max_batch, row.fill_ms)
                .with_window_ms(opts.coalesce_window_ms)
                .on_platform(host, row.util_frac),
            );
        }
    }
    models
}

/// A contention-configured [`SimFleet`] at a chosen replica count per row.
pub(crate) fn sim_fleet<F>(
    rows: &[(&FleetPlan, String)],
    opts: &WhatIfOptions,
    replicas: F,
) -> Result<SimFleet>
where
    F: Fn(&crate::fleetplan::NetworkPlan) -> u64,
{
    let mut fleet = SimFleet::new(&service_models(rows, opts, replicas))?;
    fleet.set_contention_alpha(opts.contention_alpha);
    Ok(fleet)
}

/// One production-configured [`Autoscaler`] per device sub-plan (each
/// budget-checks its own platform; `decide` ignores the other devices'
/// networks), judging with `policy`. With a `pool` attached, every scaler
/// also gets the pool and the default [`ReconfigPolicy`] — an exhausted
/// device may then emit amortized rebinds onto idle pool devices, rehearsed
/// on the virtual clock through `SimFleet::rebind_device`.
pub(crate) fn scalers_for(
    rows: &[(&FleetPlan, String)],
    pool: Option<&DevicePool>,
    opts: &WhatIfOptions,
    policy: &SloPolicy,
) -> Vec<Autoscaler> {
    rows.iter()
        .map(|(plan, _)| {
            let templates: Vec<ShardSpec> = plan
                .networks
                .iter()
                .map(|n| ShardSpec::golden(&n.network).with_queue_cap(opts.queue_cap))
                .collect();
            let scaler = if opts.latency_slo {
                Autoscaler::with_latency_slo((*plan).clone(), policy.clone(), templates)
            } else {
                Autoscaler::new((*plan).clone(), policy.clone(), templates)
            };
            match pool {
                Some(p) => scaler.with_pool(p.clone(), ReconfigPolicy::default()),
                None => scaler,
            }
        })
        .collect()
}

/// Bisect the max steady offered load the *fully-planned* fleet absorbs.
///
/// A probe rate is "sustained" only when BOTH hold: admission overload ≤
/// `opts.sustain_overload`, AND the run finishes close to the trace's own
/// duration. The second criterion matters because bounded queues can
/// swallow a short probe's entire excess without a single rejection (total
/// slots = replicas × queue_cap can exceed the end-of-probe backlog); a
/// fleet that is merely *buffering* an unsustainable rate reveals itself by
/// the drain tail — completions lag arrivals, so virtual end time runs past
/// the offered window. The 2% lag margin leaves room for ordinary queueing
/// fluctuation at capacity while rejecting any rate meaningfully above it.
fn max_sustainable_qps(
    rows: &[(&FleetPlan, String)],
    mix: &[(String, f64)],
    seed: u64,
    opts: &WhatIfOptions,
) -> Result<f64> {
    let ceiling = capacity_qps(rows, mix, opts, |row| row.replicas);
    if ceiling <= 0.0 {
        return Ok(0.0);
    }
    let (mut lo, mut hi) = (0.0f64, ceiling * 1.25 + 1.0);
    for probe in 0..14u64 {
        let qps = 0.5 * (lo + hi);
        let duration_ms = (opts.probe_arrivals as f64 / qps * 1e3).max(1.0);
        let scenario = Scenario::new(
            super::workload::ScenarioShape::Steady,
            mix.to_vec(),
            qps,
            duration_ms,
            seed ^ (0xB15E_C7 + probe),
        );
        let trace = scenario.arrivals();
        // Lag margin: a full coalesced batch is the largest indivisible
        // chunk of virtual service time, so the drain tail of a healthy
        // run is a few of those, not a few single-request times.
        let models = service_models(rows, opts, |row| row.replicas);
        let max_service_ms = models
            .iter()
            .map(|m| {
                let fill = m.fill_ns.min(m.service_ns.saturating_sub(1));
                let batch =
                    fill + (m.service_ns - fill).saturating_mul(m.max_batch.max(1) as u64);
                batch as f64 / 1e6
            })
            .fold(0.0f64, f64::max);
        let mut fleet = SimFleet::new(&models)?;
        fleet.set_contention_alpha(opts.contention_alpha);
        let run = simulate_trace(
            &mut fleet,
            &trace,
            &mut [],
            &SimRunOptions {
                control_interval_ms: opts.control_interval_ms,
                cooldown_ticks: 0,
            },
        )?;
        let overload =
            if run.offered == 0 { 0.0 } else { run.rejected as f64 / run.offered as f64 };
        let lag_ok = run.virtual_ms <= duration_ms * 1.02 + 4.0 * max_service_ms;
        if overload <= opts.sustain_overload && lag_ok {
            lo = qps;
        } else {
            hi = qps;
        }
    }
    Ok((lo * 10.0).round() / 10.0)
}

/// One controlled run: floors-start fleet + production autoscalers judging
/// with `policy`, over `trace`. Returns the run and the final routable
/// replica counts. The shared engine entry of [`explore`] and
/// `policysearch::search`.
pub(crate) fn run_controlled(
    spill: &SpillPlan,
    trace: &Trace,
    policy: &SloPolicy,
    opts: &WhatIfOptions,
) -> Result<(super::engine::SimRun, std::collections::BTreeMap<String, usize>)> {
    let (run, counts, _) = run_controlled_rows(&plan_rows(spill), None, trace, policy, opts)?;
    Ok((run, counts))
}

/// N-device generalization of [`run_controlled`]: one `(plan, host)` row per
/// device, plus the optional [`DevicePool`] that arms the controllers'
/// reconfiguration-aware rebind path.
pub(crate) fn run_controlled_rows(
    rows: &[(&FleetPlan, String)],
    pool: Option<&DevicePool>,
    trace: &Trace,
    policy: &SloPolicy,
    opts: &WhatIfOptions,
) -> Result<(
    super::engine::SimRun,
    std::collections::BTreeMap<String, usize>,
    Option<DriftReport>,
)> {
    // Start at the floors; the controller earns every further replica.
    let mut fleet = sim_fleet(rows, opts, |row| row.min_replicas)?;
    let mut scalers = scalers_for(rows, pool, opts, policy);
    if let Some(obs) = &opts.obs {
        // Full plane, not just the hub sink: per-(network, replica) rings
        // give the drift monitor batch attribution and `obs::trace` a
        // serialized per-worker timeline to assemble, exactly as live.
        fleet.set_telemetry(Arc::clone(obs));
        scalers = scalers.into_iter().map(|s| s.with_obs(Arc::clone(obs))).collect();
    }
    let run = simulate_trace(
        &mut fleet,
        trace,
        &mut scalers,
        &SimRunOptions {
            control_interval_ms: opts.control_interval_ms,
            cooldown_ticks: opts.cooldown_ticks,
        },
    )?;
    let final_counts = fleet.replica_counts();
    // Score the models the planner trusted against the batches the run
    // actually recorded — same monitor, same rings, same thresholds as the
    // live plane. Runs before the capacity probes so the rings hold only
    // the main run's spans.
    let drift = opts.obs.as_ref().map(|obs| {
        let mut monitor =
            DriftMonitor::new(fleet.drift_expectations(opts.contention_alpha));
        monitor.report(obs, run.virtual_ms)
    });
    Ok((run, final_counts, drift))
}

/// Shared back half of [`explore`] / [`explore_replay`] / [`explore_pool`]:
/// run the main trace with the production controller in the loop and
/// assemble the report. `platform` / `spill_platform` label the report (for
/// pool runs: the first used device, no spill).
#[allow(clippy::too_many_arguments)]
fn explore_with_trace(
    rows: &[(&FleetPlan, String)],
    pool: Option<&DevicePool>,
    platform: String,
    spill_platform: Option<String>,
    scenario_name: &str,
    seed: u64,
    qps: f64,
    mix: &[(String, f64)],
    trace: &Trace,
    opts: &WhatIfOptions,
) -> Result<CapacityReport> {
    let (run, final_counts, drift) =
        run_controlled_rows(rows, pool, trace, &opts.policy, opts)?;

    let mut networks = Vec::new();
    for (plan, host) in rows {
        for row in &plan.networks {
            let sim = run.networks.iter().find(|n| n.network == row.network);
            let peak = run
                .trajectory
                .iter()
                .filter(|p| p.network == row.network)
                .map(|p| p.replicas)
                .max()
                .unwrap_or(row.min_replicas as usize);
            networks.push(NetworkCapacity {
                network: row.network.clone(),
                platform: host.clone(),
                predicted_ms: row.predicted_ms,
                planned_replicas: row.replicas,
                start_replicas: row.min_replicas,
                peak_replicas: peak,
                final_replicas: final_counts.get(&row.network).copied().unwrap_or(0),
                offered: sim.map(|s| s.offered).unwrap_or(0),
                admitted: sim.map(|s| s.admitted).unwrap_or(0),
                rejected: sim.map(|s| s.rejected).unwrap_or(0),
                overload_rate: sim.map(|s| s.overload_rate).unwrap_or(0.0),
                mean_ms: sim.map(|s| s.mean_ms).unwrap_or(0.0),
                p95_ms: sim.map(|s| s.p95_ms).unwrap_or(0.0),
            });
        }
    }
    networks.sort_by(|a, b| a.network.cmp(&b.network));

    let scale_ups =
        run.decisions.iter().filter(|d| d.action == ScaleAction::Up).count();
    // Explicit Down filter: rebinds belong to neither counter.
    let scale_downs =
        run.decisions.iter().filter(|d| d.action == ScaleAction::Down).count();
    let decisions: Vec<String> =
        run.decisions.iter().map(|d| format!("t=+{:.3}ms {}", d.at_ms, d)).collect();

    let max_qps = max_sustainable_qps(rows, mix, seed, opts)?;
    let stages = match &opts.obs {
        Some(obs) => obs.registry().histogram_rows(),
        None => Vec::new(),
    };
    Ok(CapacityReport {
        scenario: scenario_name.to_string(),
        seed,
        platform,
        spill_platform,
        cap: opts.cap,
        qps,
        events: run.events,
        virtual_ms: run.virtual_ms,
        max_sustainable_qps: max_qps,
        networks,
        trajectory: run.trajectory,
        decisions,
        scale_ups,
        scale_downs,
        stages,
        drift,
    })
}

/// Explore one scenario: plan (with spill fallback), auto-size the
/// workload, simulate with the production controller, bisect capacity.
///
/// Scenario auto-completion: an empty `mix` is filled from the demand
/// weights; `qps == 0` becomes 1.5× the floor configuration's closed-form
/// capacity (so the floors overload and the controller must act);
/// `duration_ms == 0` is sized so at least `opts.min_arrivals` arrivals are
/// generated (burst/diurnal periods rescale with it).
pub fn explore(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    platforms: &[Platform],
    scenario: &Scenario,
    opts: &WhatIfOptions,
) -> Result<CapacityReport> {
    let spill = select_platform_or_spill(demands, registry, platforms, opts.cap)?;
    let sc = autosize_scenario(scenario, demands, &spill, opts)?;
    let trace = sc.arrivals();
    explore_with_trace(
        &plan_rows(&spill),
        None,
        spill.primary.platform.name.to_string(),
        spill.spill.as_ref().map(|s| s.platform.name.to_string()),
        sc.shape.name(),
        sc.seed,
        sc.qps,
        &sc.mix,
        &trace,
        opts,
    )
}

/// Explore a heterogeneous [`DevicePool`]: pack the fleet across the pool
/// with [`plan_pool`], then run the same controller-in-the-loop simulation
/// against the per-device contention groups. Devices the plan left empty
/// stay out of the simulation but remain available to the controller as
/// rebind targets — each unused device keeps its input binding, each used
/// device is bound to its first planned network so the controller's
/// thrash guard sees the live bitstreams.
///
/// The report's `platform` is the first *used* device's name;
/// `spill_platform` is `None` (a pool has no special spill device).
pub fn explore_pool(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    pool: &DevicePool,
    scenario: &Scenario,
    opts: &WhatIfOptions,
) -> Result<CapacityReport> {
    let pool_plan = plan_pool(demands, registry, pool)?;
    let mut bound = pool.clone();
    for dev in bound.devices.iter_mut() {
        if dev.binding.is_none() {
            if let Some(dp) = pool_plan.devices.iter().find(|dp| dp.device == dev.name) {
                dev.binding = dp.plan.networks.first().map(|row| row.network.clone());
            }
        }
    }
    let rows = pool_rows(&pool_plan);
    let platform = match rows.first() {
        Some((_, host)) => host.clone(),
        None => {
            return Err(Error::InvalidConfig(
                "the pool plan placed no replicas on any device".into(),
            ))
        }
    };
    let sc = autosize_scenario_rows(scenario, demands, &rows, opts)?;
    let trace = sc.arrivals();
    explore_with_trace(
        &rows,
        Some(&bound),
        platform,
        None,
        sc.shape.name(),
        sc.seed,
        sc.qps,
        &sc.mix,
        &trace,
        opts,
    )
}

/// Scenario auto-completion shared by [`explore`] and
/// `policysearch::search`: fill an empty mix from the demand weights, an
/// unset QPS from 1.5× the floor configuration's closed-form capacity, an
/// unset duration from the `min_arrivals` floor (burst/diurnal periods
/// rescale with it).
pub(crate) fn autosize_scenario(
    scenario: &Scenario,
    demands: &[NetworkDemand],
    spill: &SpillPlan,
    opts: &WhatIfOptions,
) -> Result<Scenario> {
    autosize_scenario_rows(scenario, demands, &plan_rows(spill), opts)
}

/// Row-slice core of [`autosize_scenario`], shared with [`explore_pool`].
pub(crate) fn autosize_scenario_rows(
    scenario: &Scenario,
    demands: &[NetworkDemand],
    rows: &[(&FleetPlan, String)],
    opts: &WhatIfOptions,
) -> Result<Scenario> {
    let mut sc = scenario.clone();
    if sc.mix.is_empty() {
        sc.mix = demands
            .iter()
            .map(|d| (d.spec.name.clone(), if d.weight > 0.0 { d.weight } else { 1.0 }))
            .collect();
    }
    if sc.qps <= 0.0 {
        let floors = capacity_qps(rows, &sc.mix, opts, |row| row.min_replicas);
        if floors <= 0.0 {
            return Err(Error::InvalidConfig(
                "cannot auto-size QPS: zero floor capacity (check the traffic mix)".into(),
            ));
        }
        sc.qps = 1.5 * floors;
    }
    if sc.duration_ms <= 0.0 {
        sc.duration_ms = (opts.min_arrivals as f64 / sc.qps * 1e3).max(1.0);
        let period = (sc.duration_ms / 5.0).max(1.0);
        sc.burst_period_ms = period;
        sc.burst_len_ms = period * 0.15;
    }
    Ok(sc)
}

/// Explore a *recorded* trace (see
/// `coordinator::drive_golden_clients_traced`): the live run's arrival
/// pattern replays against the model-predicted fleet, mix and QPS are
/// derived from the trace itself.
pub fn explore_replay(
    demands: &[NetworkDemand],
    registry: &ModelRegistry,
    platforms: &[Platform],
    trace: &Trace,
    seed: u64,
    opts: &WhatIfOptions,
) -> Result<CapacityReport> {
    if trace.is_empty() {
        return Err(Error::InvalidConfig("replay trace has no arrivals".into()));
    }
    let spill = select_platform_or_spill(demands, registry, platforms, opts.cap)?;
    let mut mix: Vec<(String, f64)> = Vec::new();
    for e in &trace.events {
        let name = trace.network_of(e);
        match mix.iter_mut().find(|(n, _)| n == name) {
            Some((_, w)) => *w += 1.0,
            None => mix.push((name.to_string(), 1.0)),
        }
    }
    mix.sort_by(|a, b| a.0.cmp(&b.0));
    let qps = trace.len() as f64 / (trace.duration_ms() / 1e3).max(1e-9);
    explore_with_trace(
        &plan_rows(&spill),
        None,
        spill.primary.platform.name.to_string(),
        spill.spill.as_ref().map(|s| s.platform.name.to_string()),
        "replay",
        seed,
        qps,
        &mix,
        trace,
        opts,
    )
}
