//! Seeded traffic scenarios and the trace record/replay format.
//!
//! A [`Scenario`] turns `(shape, seed, qps, duration, network mix)` into a
//! [`Trace`] — a time-sorted list of request arrivals — via a seeded
//! [`SplitMix64`] stream, so the same scenario always produces the
//! byte-identical workload. Non-homogeneous shapes (diurnal, burst) are
//! sampled by *thinning*: candidate arrivals are drawn from a homogeneous
//! Poisson process at the peak rate and accepted with probability
//! `rate(t) / peak`, which keeps the generator exact for any rate curve.
//! The heavy-tail shape draws Pareto inter-arrival gaps (same mean as the
//! requested QPS, shape `tail_alpha`), modelling the bursty arrival
//! clumping real traffic shows.
//!
//! Traces are also how real runs become simulations: a [`TraceRecorder`]
//! passed to `coordinator::drive_golden_clients_traced` captures every
//! offered request with a wall-clock-relative timestamp, and the resulting
//! trace replays through the simulator exactly like a synthetic one
//! ([`Trace::save`] / [`Trace::load`] round-trip through a one-line-per-
//! event CSV).

use super::clock::SimNs;
use crate::util::error::{Error, Result};
use crate::util::rng::SplitMix64;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// The shape of a traffic scenario's offered-rate curve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioShape {
    /// Constant mean rate (Poisson arrivals).
    Steady,
    /// Sinusoidal day/night modulation around the mean rate.
    Diurnal,
    /// Baseline with periodic spike windows at a multiple of the base rate.
    Burst,
    /// Pareto inter-arrival gaps: same mean rate, heavy-tailed clumping.
    HeavyTail,
}

impl ScenarioShape {
    /// Parse a CLI scenario name (`spike` is an alias for `burst`).
    pub fn parse(name: &str) -> Option<ScenarioShape> {
        match name.to_ascii_lowercase().as_str() {
            "steady" => Some(ScenarioShape::Steady),
            "diurnal" => Some(ScenarioShape::Diurnal),
            "burst" | "spike" => Some(ScenarioShape::Burst),
            "heavytail" | "heavy-tail" | "heavy_tail" => Some(ScenarioShape::HeavyTail),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioShape::Steady => "steady",
            ScenarioShape::Diurnal => "diurnal",
            ScenarioShape::Burst => "burst",
            ScenarioShape::HeavyTail => "heavytail",
        }
    }
}

/// A parameterized traffic scenario over a multi-network mix.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Rate-curve shape.
    pub shape: ScenarioShape,
    /// Generator seed (same seed + parameters ⇒ byte-identical trace).
    pub seed: u64,
    /// Mean offered load, aggregate over all networks (requests/s of
    /// *virtual* time).
    pub qps: f64,
    /// Virtual duration of the scenario (ms).
    pub duration_ms: f64,
    /// `(network, weight)` traffic mix; each arrival picks a network with
    /// probability proportional to its weight.
    pub mix: Vec<(String, f64)>,
    /// Burst peak as a multiple of the baseline rate (also sets the
    /// diurnal peak-to-trough ratio).
    pub burst_factor: f64,
    /// Burst (and diurnal) period (virtual ms).
    pub burst_period_ms: f64,
    /// Burst window length within each period (virtual ms).
    pub burst_len_ms: f64,
    /// Pareto shape for [`ScenarioShape::HeavyTail`] (> 1; smaller = wilder).
    pub tail_alpha: f64,
}

impl Scenario {
    /// A scenario with shape-appropriate defaults: burst/diurnal period is a
    /// fifth of the duration (so every run sees several cycles), bursts
    /// occupy 15% of each period at 8× the baseline, and the heavy tail is
    /// Pareto(1.5).
    ///
    /// ```
    /// use convkit::simulate::{Scenario, ScenarioShape};
    /// let s = Scenario::new(
    ///     ScenarioShape::Steady,
    ///     vec![("lenet_q8".to_string(), 1.0)],
    ///     1_000.0, // mean offered qps (virtual)
    ///     100.0,   // duration (virtual ms)
    ///     7,       // seed
    /// );
    /// let trace = s.arrivals();
    /// assert!(!trace.is_empty());
    /// assert_eq!(trace, s.arrivals(), "same seed ⇒ byte-identical trace");
    /// ```
    pub fn new(
        shape: ScenarioShape,
        mix: Vec<(String, f64)>,
        qps: f64,
        duration_ms: f64,
        seed: u64,
    ) -> Scenario {
        let period = (duration_ms / 5.0).max(1.0);
        Scenario {
            shape,
            seed,
            qps,
            duration_ms,
            mix,
            burst_factor: 8.0,
            burst_period_ms: period,
            burst_len_ms: period * 0.15,
            tail_alpha: 1.5,
        }
    }

    /// Diurnal amplitude in (0, 1) such that peak/trough = `burst_factor`.
    fn diurnal_amplitude(&self) -> f64 {
        let f = self.burst_factor.max(1.0);
        (f - 1.0) / (f + 1.0)
    }

    /// Burst baseline rate such that the long-run mean is `qps`.
    fn burst_base(&self) -> f64 {
        let frac = (self.burst_len_ms / self.burst_period_ms).clamp(0.0, 1.0);
        self.qps / (1.0 - frac + self.burst_factor.max(1.0) * frac)
    }

    /// Instantaneous offered rate at virtual second `t_s`.
    fn rate_at(&self, t_s: f64) -> f64 {
        match self.shape {
            ScenarioShape::Steady | ScenarioShape::HeavyTail => self.qps,
            ScenarioShape::Diurnal => {
                let period_s = self.burst_period_ms / 1e3;
                let a = self.diurnal_amplitude();
                self.qps * (1.0 + a * (std::f64::consts::TAU * t_s / period_s).sin())
            }
            ScenarioShape::Burst => {
                let period_s = self.burst_period_ms / 1e3;
                let phase = t_s % period_s;
                let base = self.burst_base();
                if phase < self.burst_len_ms / 1e3 {
                    base * self.burst_factor.max(1.0)
                } else {
                    base
                }
            }
        }
    }

    /// Peak of the rate curve (the thinning envelope).
    fn peak_rate(&self) -> f64 {
        match self.shape {
            ScenarioShape::Steady | ScenarioShape::HeavyTail => self.qps,
            ScenarioShape::Diurnal => self.qps * (1.0 + self.diurnal_amplitude()),
            ScenarioShape::Burst => self.burst_base() * self.burst_factor.max(1.0),
        }
    }

    /// Generate the arrival trace: deterministic in every field + `seed`.
    /// An empty mix produces an empty trace (there is no one to call).
    pub fn arrivals(&self) -> Trace {
        if self.mix.is_empty() {
            return Trace::default();
        }
        let mut rng = SplitMix64::new(self.seed ^ 0x5C3A_AA10_7A11_F00D);
        let networks: Vec<String> = self.mix.iter().map(|(n, _)| n.clone()).collect();
        let weights: Vec<f64> =
            self.mix.iter().map(|(_, w)| if *w > 0.0 { *w } else { 1.0 }).collect();
        let total_w: f64 = weights.iter().sum();
        let qps = self.qps.max(1e-9);
        let peak = self.peak_rate().max(1e-9);
        let alpha = self.tail_alpha.max(1.01);
        let dur_s = self.duration_ms / 1e3;
        let mut events = Vec::new();
        let mut t_s = 0.0f64;
        loop {
            match self.shape {
                ScenarioShape::HeavyTail => {
                    // Pareto(xm, alpha) with mean 1/qps: xm = mean·(α−1)/α.
                    let xm = (1.0 / qps) * (alpha - 1.0) / alpha;
                    t_s += xm / (1.0 - rng.next_f64()).powf(1.0 / alpha);
                }
                _ => {
                    // Homogeneous candidate at the peak rate...
                    t_s += -(1.0 - rng.next_f64()).ln() / peak;
                }
            }
            if t_s >= dur_s {
                break;
            }
            // ...thinned to the instantaneous rate (always accepted for the
            // constant-envelope shapes).
            if !matches!(self.shape, ScenarioShape::HeavyTail)
                && rng.next_f64() * peak > self.rate_at(t_s)
            {
                continue;
            }
            let mut pick = rng.next_f64() * total_w;
            let mut net = 0u32;
            for (i, w) in weights.iter().enumerate() {
                pick -= w;
                if pick <= 0.0 {
                    net = i as u32;
                    break;
                }
            }
            events.push(TraceEvent { at_ns: (t_s * 1e9) as SimNs, net });
        }
        Trace { networks, events }
    }
}

/// One offered request: arrival time + interned network index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual arrival time (ns).
    pub at_ns: SimNs,
    /// Index into [`Trace::networks`].
    pub net: u32,
}

/// A time-sorted arrival list over an interned network table (interning
/// keeps a million-event trace at 12 bytes per event instead of a `String`
/// allocation each).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    /// Interned network names.
    pub networks: Vec<String>,
    /// Arrivals, ascending `at_ns` (insertion order within a tick).
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the trace has no arrivals.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Time of the last arrival (ms).
    pub fn duration_ms(&self) -> f64 {
        self.events.last().map(|e| e.at_ns as f64 / 1e6).unwrap_or(0.0)
    }

    /// Network name of one event.
    pub fn network_of(&self, e: &TraceEvent) -> &str {
        &self.networks[e.net as usize]
    }

    /// Save as CSV. The trace format (produced here and by
    /// `convkit fleet --record`, consumed by `convkit simulate --replay`):
    ///
    /// ```text
    /// at_ns,network
    /// 0,lenet_q8
    /// 137208,tiny_q8
    /// 212992,lenet_q8
    /// ```
    ///
    /// One line per offered request: `at_ns` is the arrival instant in
    /// nanoseconds (virtual time for generated traces, wall offset from
    /// recorder construction for recorded ones) and `network` is the
    /// routing key. Lines need not be sorted on disk —
    /// [`Trace::load`] re-sorts by timestamp — and blank lines or repeated
    /// header lines are skipped, so hand-edited traces are tolerated.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut out = String::with_capacity(self.events.len() * 24 + 16);
        out.push_str("at_ns,network\n");
        for e in &self.events {
            out.push_str(&format!("{},{}\n", e.at_ns, self.network_of(e)));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Load a CSV written by [`Trace::save`] (events re-sorted by time, so
    /// hand-edited traces are tolerated).
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let mut trace = Trace::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with("at_ns") {
                continue;
            }
            let (at, name) = line.split_once(',').ok_or_else(|| {
                Error::Parse(format!("{}:{}: expected `at_ns,network`", path.display(), lineno + 1))
            })?;
            let at_ns: SimNs = at.trim().parse().map_err(|_| {
                Error::Parse(format!("{}:{}: bad timestamp `{at}`", path.display(), lineno + 1))
            })?;
            let name = name.trim();
            let net = match trace.networks.iter().position(|n| n == name) {
                Some(i) => i as u32,
                None => {
                    trace.networks.push(name.to_string());
                    (trace.networks.len() - 1) as u32
                }
            };
            trace.events.push(TraceEvent { at_ns, net });
        }
        trace.events.sort_by_key(|e| e.at_ns);
        Ok(trace)
    }
}

/// Captures offered requests from a *live* run (wall-clock timestamps
/// relative to construction) into a replayable [`Trace`]. Thread-safe: the
/// serving drivers call [`TraceRecorder::note`] from one client thread per
/// network.
pub struct TraceRecorder {
    epoch: Instant,
    inner: Mutex<RecorderInner>,
}

#[derive(Default)]
struct RecorderInner {
    networks: Vec<String>,
    events: Vec<TraceEvent>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    /// A recorder whose t = 0 is now.
    pub fn new() -> TraceRecorder {
        TraceRecorder { epoch: Instant::now(), inner: Mutex::new(RecorderInner::default()) }
    }

    /// Record one offered request for `network` at the current wall offset.
    pub fn note(&self, network: &str) {
        let at_ns = self.epoch.elapsed().as_nanos() as SimNs;
        let mut inner = self.inner.lock().expect("trace recorder poisoned");
        let net = match inner.networks.iter().position(|n| n == network) {
            Some(i) => i as u32,
            None => {
                inner.networks.push(network.to_string());
                (inner.networks.len() - 1) as u32
            }
        };
        inner.events.push(TraceEvent { at_ns, net });
    }

    /// Finish recording: a time-sorted, replayable trace.
    pub fn into_trace(self) -> Trace {
        let inner = self.inner.into_inner().expect("trace recorder poisoned");
        let mut trace = Trace { networks: inner.networks, events: inner.events };
        trace.events.sort_by_key(|e| e.at_ns);
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> Vec<(String, f64)> {
        vec![("a".to_string(), 3.0), ("b".to_string(), 1.0)]
    }

    #[test]
    fn scenario_names_round_trip() {
        for shape in [
            ScenarioShape::Steady,
            ScenarioShape::Diurnal,
            ScenarioShape::Burst,
            ScenarioShape::HeavyTail,
        ] {
            assert_eq!(ScenarioShape::parse(shape.name()), Some(shape));
        }
        assert_eq!(ScenarioShape::parse("spike"), Some(ScenarioShape::Burst));
        assert_eq!(ScenarioShape::parse("heavy-tail"), Some(ScenarioShape::HeavyTail));
        assert_eq!(ScenarioShape::parse("nope"), None);
    }

    #[test]
    fn same_seed_same_trace_different_seed_different() {
        for shape in [
            ScenarioShape::Steady,
            ScenarioShape::Diurnal,
            ScenarioShape::Burst,
            ScenarioShape::HeavyTail,
        ] {
            let s = Scenario::new(shape, mix(), 500.0, 2_000.0, 42);
            let a = s.arrivals();
            let b = s.arrivals();
            assert_eq!(a, b, "{shape:?}: same seed must replay identically");
            let other = Scenario::new(shape, mix(), 500.0, 2_000.0, 43).arrivals();
            assert_ne!(a, other, "{shape:?}: different seed must diverge");
        }
    }

    #[test]
    fn arrival_counts_track_the_requested_qps() {
        for shape in [ScenarioShape::Steady, ScenarioShape::Diurnal, ScenarioShape::Burst] {
            let s = Scenario::new(shape, mix(), 1_000.0, 10_000.0, 7);
            let t = s.arrivals();
            let expected = 1_000.0 * 10.0;
            let got = t.len() as f64;
            assert!(
                (got - expected).abs() < expected * 0.15,
                "{shape:?}: {got} arrivals vs ~{expected} expected"
            );
        }
    }

    #[test]
    fn arrivals_are_sorted_and_within_duration() {
        let s = Scenario::new(ScenarioShape::Burst, mix(), 2_000.0, 3_000.0, 9);
        let t = s.arrivals();
        let dur_ns = 3_000u64 * 1_000_000;
        let mut last = 0;
        for e in &t.events {
            assert!(e.at_ns >= last, "sorted");
            assert!(e.at_ns < dur_ns, "within duration");
            last = e.at_ns;
        }
    }

    #[test]
    fn mix_weights_shape_the_network_split() {
        let s = Scenario::new(ScenarioShape::Steady, mix(), 2_000.0, 5_000.0, 11);
        let t = s.arrivals();
        let a = t.events.iter().filter(|e| t.network_of(e) == "a").count() as f64;
        let b = t.events.iter().filter(|e| t.network_of(e) == "b").count() as f64;
        let ratio = a / b.max(1.0);
        assert!((2.0..4.5).contains(&ratio), "3:1 weights, observed {ratio:.2}:1");
    }

    #[test]
    fn heavy_tail_keeps_the_mean_but_clumps() {
        let s = Scenario::new(ScenarioShape::HeavyTail, mix(), 1_000.0, 20_000.0, 13);
        let t = s.arrivals();
        let expected = 1_000.0 * 20.0;
        // Pareto(1.5) sums converge slowly (infinite variance): very
        // generous mean tolerance — the assertion is about magnitude, the
        // seeded stream keeps the exact count reproducible.
        assert!(
            (t.len() as f64) > expected * 0.3 && (t.len() as f64) < expected * 3.0,
            "{} arrivals vs ~{expected}",
            t.len()
        );
        // Clumping: the maximum gap dwarfs the mean gap.
        let mut max_gap = 0u64;
        for w in t.events.windows(2) {
            max_gap = max_gap.max(w[1].at_ns - w[0].at_ns);
        }
        let mean_gap_ns = 1e9 / 1_000.0;
        assert!(
            max_gap as f64 > 8.0 * mean_gap_ns,
            "heavy tail should show gaps ≫ mean ({max_gap} ns vs mean {mean_gap_ns} ns)"
        );
    }

    #[test]
    fn trace_save_load_round_trips() {
        let dir = std::env::temp_dir().join("convkit_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let s = Scenario::new(ScenarioShape::Steady, mix(), 200.0, 1_000.0, 21);
        let t = s.arrivals();
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t.len(), back.len());
        for (x, y) in t.events.iter().zip(&back.events) {
            assert_eq!(x.at_ns, y.at_ns);
            assert_eq!(t.network_of(x), back.network_of(y));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recorder_produces_a_sorted_replayable_trace() {
        let rec = TraceRecorder::new();
        rec.note("beta");
        rec.note("alpha");
        rec.note("beta");
        let t = rec.into_trace();
        assert_eq!(t.len(), 3);
        assert_eq!(t.networks, vec!["beta".to_string(), "alpha".to_string()]);
        let mut last = 0;
        for e in &t.events {
            assert!(e.at_ns >= last);
            last = e.at_ns;
        }
    }
}
