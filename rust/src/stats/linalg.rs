//! Dense matrices and Householder-QR least squares.

use crate::util::error::{Error, Result};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Result<Mat> {
        if data.len() != rows * cols {
            return Err(Error::Numerical(format!(
                "matrix data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data: data.to_vec() })
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0;
            for c in 0..self.cols {
                acc += self[(r, c)] * v[c];
            }
            out[r] = acc;
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `A^T A` (used for the covariance of the fitted coefficients).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        for i in 0..self.cols {
            for j in i..self.cols {
                let mut acc = 0.0;
                for r in 0..self.rows {
                    acc += self[(r, i)] * self[(r, j)];
                }
                g[(i, j)] = acc;
                g[(j, i)] = acc;
            }
        }
        g
    }

    /// Solve the least-squares problem `min ||A x - b||₂` by Householder QR.
    /// Requires `rows >= cols`; returns `Err` on rank deficiency.
    pub fn lstsq(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.rows {
            return Err(Error::Numerical(format!(
                "rhs length {} != rows {}",
                b.len(),
                self.rows
            )));
        }
        if self.rows < self.cols {
            return Err(Error::Numerical(format!(
                "underdetermined system {}x{}",
                self.rows, self.cols
            )));
        }
        let mut a = self.clone();
        let mut y = b.to_vec();
        let (m, n) = (a.rows, a.cols);
        let mut v = vec![0.0f64; m]; // reflector scratch
        // Householder triangularization, applying reflectors to y as we go.
        for k in 0..n {
            // Column norm at/below the diagonal.
            let mut norm = 0.0;
            for i in k..m {
                norm += a[(i, k)] * a[(i, k)];
            }
            norm = norm.sqrt();
            if norm < 1e-12 {
                return Err(Error::Numerical(format!("rank-deficient at column {k}")));
            }
            let alpha = if a[(k, k)] >= 0.0 { -norm } else { norm };
            // v = x - alpha·e1, held in scratch so column k can be updated.
            v[k] = a[(k, k)] - alpha;
            let mut vnorm2 = v[k] * v[k];
            for i in k + 1..m {
                v[i] = a[(i, k)];
                vnorm2 += v[i] * v[i];
            }
            if vnorm2 < 1e-300 {
                a[(k, k)] = alpha;
                continue;
            }
            // Apply H = I - 2 v vᵀ / (vᵀ v) to A[:, k..] and to y.
            for j in k..n {
                let mut dot = 0.0;
                for i in k..m {
                    dot += v[i] * a[(i, j)];
                }
                let f = 2.0 * dot / vnorm2;
                for i in k..m {
                    a[(i, j)] -= f * v[i];
                }
            }
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i] * y[i];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                y[i] -= f * v[i];
            }
        }
        // Back substitution on the triangular system R x = y[..n].
        let mut x = vec![0.0f64; n];
        for k in (0..n).rev() {
            let mut acc = y[k];
            for j in k + 1..n {
                acc -= a[(k, j)] * x[j];
            }
            let rkk = a[(k, k)];
            if rkk.abs() < 1e-12 {
                return Err(Error::Numerical(format!("zero pivot at row {k}")));
            }
            x[k] = acc / rkk;
        }
        Ok(x)
    }

    /// Inverse via Gauss-Jordan with partial pivoting (square matrices only).
    pub fn inverse(&self) -> Result<Mat> {
        if self.rows != self.cols {
            return Err(Error::Numerical("inverse of non-square matrix".into()));
        }
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Mat::eye(n);
        for col in 0..n {
            // Pivot.
            let mut piv = col;
            for r in col + 1..n {
                if a[(r, col)].abs() > a[(piv, col)].abs() {
                    piv = r;
                }
            }
            if a[(piv, col)].abs() < 1e-12 {
                return Err(Error::Numerical(format!("singular matrix at column {col}")));
            }
            if piv != col {
                for j in 0..n {
                    let tmp = a[(col, j)];
                    a[(col, j)] = a[(piv, j)];
                    a[(piv, j)] = tmp;
                    let tmp = inv[(col, j)];
                    inv[(col, j)] = inv[(piv, j)];
                    inv[(piv, j)] = tmp;
                }
            }
            let d = a[(col, col)];
            for j in 0..n {
                a[(col, j)] /= d;
                inv[(col, j)] /= d;
            }
            for r in 0..n {
                if r != col {
                    let f = a[(r, col)];
                    if f != 0.0 {
                        for j in 0..n {
                            a[(r, j)] -= f * a[(col, j)];
                            inv[(r, j)] -= f * inv[(col, j)];
                        }
                    }
                }
            }
        }
        Ok(inv)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(0, 1)], 4.0);
    }

    #[test]
    fn inverse_of_known_matrix() {
        let a = Mat::from_rows(2, 2, &[4.0, 7.0, 2.0, 6.0]).unwrap();
        let inv = a.inverse().unwrap();
        assert!((inv[(0, 0)] - 0.6).abs() < 1e-12);
        assert!((inv[(0, 1)] + 0.7).abs() < 1e-12);
        assert!((inv[(1, 0)] + 0.2).abs() < 1e-12);
        assert!((inv[(1, 1)] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]).unwrap();
        assert!(a.inverse().is_err());
    }

    #[test]
    fn lstsq_exact_square_system() {
        // [2 1; 1 3] x = [5; 10] -> x = [1, 3]
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 3.0]).unwrap();
        let x = a.lstsq(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-10, "{x:?}");
        assert!((x[1] - 3.0).abs() < 1e-10, "{x:?}");
    }

    #[test]
    fn lstsq_overdetermined_line_fit() {
        // Fit y = 2 + 3t through exact points: residual 0.
        let ts = [0.0, 1.0, 2.0, 3.0, 4.0];
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for &t in &ts {
            rows.extend_from_slice(&[1.0, t]);
            y.push(2.0 + 3.0 * t);
        }
        let a = Mat::from_rows(5, 2, &rows).unwrap();
        let x = a.lstsq(&y).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-10);
        assert!((x[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn lstsq_noisy_regression_matches_normal_equations() {
        // y = 1 + 2a - b with a known perturbation; compare against the
        // closed-form normal-equation solution computed by inverse().
        let data = [
            (1.0, 2.0, 3.1),
            (2.0, 1.0, 4.2),
            (3.0, 5.0, 1.9),
            (4.0, 2.0, 7.3),
            (5.0, 0.0, 11.2),
            (6.0, 4.0, 8.8),
        ];
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for &(a1, b1, yy) in &data {
            rows.extend_from_slice(&[1.0, a1, b1]);
            y.push(yy);
        }
        let a = Mat::from_rows(6, 3, &rows).unwrap();
        let x_qr = a.lstsq(&y).unwrap();
        // Normal equations: (AᵀA)⁻¹ Aᵀ y.
        let at = a.transpose();
        let aty = at.matvec(&y);
        let x_ne = a.gram().inverse().unwrap().matvec(&aty);
        for i in 0..3 {
            assert!((x_qr[i] - x_ne[i]).abs() < 1e-8, "{x_qr:?} vs {x_ne:?}");
        }
    }

    #[test]
    fn lstsq_rejects_rank_deficiency_and_bad_shapes() {
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 2.0, 4.0, 3.0, 6.0]).unwrap();
        assert!(a.lstsq(&[1.0, 2.0, 3.0]).is_err(), "collinear columns");
        let a = Mat::from_rows(1, 2, &[1.0, 2.0]).unwrap();
        assert!(a.lstsq(&[1.0]).is_err(), "underdetermined");
        let a = Mat::from_rows(2, 2, &[1.0, 0.0, 0.0, 1.0]).unwrap();
        assert!(a.lstsq(&[1.0]).is_err(), "rhs length mismatch");
    }

    #[test]
    fn gram_is_symmetric_psd_diagonal() {
        let a = Mat::from_rows(3, 2, &[1.0, 0.0, 1.0, 1.0, 1.0, 2.0]).unwrap();
        let g = a.gram();
        assert_eq!(g[(0, 0)], 3.0);
        assert_eq!(g[(0, 1)], g[(1, 0)]);
        assert_eq!(g[(1, 1)], 5.0);
    }
}
