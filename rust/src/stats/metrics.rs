//! The paper's four error metrics (§4.1): EQM (MSE), EAM (MAE), R² and
//! EAMP (MAPE).

/// Mean squared error.
pub fn mse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum::<f64>()
        / y_true.len() as f64
}

/// Mean absolute error.
pub fn mae(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    y_true.iter().zip(y_pred).map(|(t, p)| (t - p).abs()).sum::<f64>() / y_true.len() as f64
}

/// Coefficient of determination. 1.0 for a perfect fit; can be negative for a
/// fit worse than the mean. For constant `y_true` returns 1.0 iff the
/// predictions are exact (the Conv3 segmented-fit convention: Table 4 reports
/// R² = 1.00 there).
pub fn r_squared(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 1.0;
    }
    let mean = y_true.iter().sum::<f64>() / y_true.len() as f64;
    let ss_tot: f64 = y_true.iter().map(|t| (t - mean) * (t - mean)).sum();
    let ss_res: f64 = y_true.iter().zip(y_pred).map(|(t, p)| (t - p) * (t - p)).sum();
    if ss_tot < 1e-300 {
        return if ss_res < 1e-300 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Mean absolute percentage error, in percent. Zero targets are skipped
/// (resource counts of zero would otherwise blow up the metric; Vivado-style
/// reporting does the same).
pub fn mape(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (t, p) in y_true.iter().zip(y_pred) {
        if t.abs() > 1e-12 {
            acc += ((t - p) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * acc / n as f64
    }
}

/// All four metrics bundled (one row of the paper's Table 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Metrics {
    /// EQM.
    pub mse: f64,
    /// EAM.
    pub mae: f64,
    /// R².
    pub r2: f64,
    /// EAMP (%).
    pub mape: f64,
}

impl Metrics {
    /// Compute all four metrics.
    pub fn of(y_true: &[f64], y_pred: &[f64]) -> Metrics {
        Metrics {
            mse: mse(y_true, y_pred),
            mae: mae(y_true, y_pred),
            r2: r_squared(y_true, y_pred),
            mape: mape(y_true, y_pred),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction() {
        let y = [1.0, 2.0, 3.0];
        let m = Metrics::of(&y, &y);
        assert_eq!(m.mse, 0.0);
        assert_eq!(m.mae, 0.0);
        assert_eq!(m.r2, 1.0);
        assert_eq!(m.mape, 0.0);
    }

    #[test]
    fn hand_computed_values() {
        let t = [2.0, 4.0, 6.0];
        let p = [3.0, 4.0, 5.0];
        assert!((mse(&t, &p) - 2.0 / 3.0).abs() < 1e-12);
        assert!((mae(&t, &p) - 2.0 / 3.0).abs() < 1e-12);
        // ss_tot = 8, ss_res = 2 -> r2 = 0.75
        assert!((r_squared(&t, &p) - 0.75).abs() < 1e-12);
        // mape = 100*(1/2 + 0 + 1/6)/3 = 22.22%
        assert!((mape(&t, &p) - 100.0 * (0.5 + 0.0 + 1.0 / 6.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn r2_constant_target_conventions() {
        assert_eq!(r_squared(&[5.0, 5.0], &[5.0, 5.0]), 1.0);
        assert_eq!(r_squared(&[5.0, 5.0], &[4.0, 6.0]), 0.0);
    }

    #[test]
    fn r2_worse_than_mean_is_negative() {
        let t = [1.0, 2.0, 3.0];
        let p = [3.0, 3.0, -3.0];
        assert!(r_squared(&t, &p) < 0.0);
    }

    #[test]
    fn mape_skips_zero_targets() {
        let t = [0.0, 2.0];
        let p = [5.0, 1.0];
        assert!((mape(&t, &p) - 50.0).abs() < 1e-12);
        assert_eq!(mape(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn empty_inputs_are_benign() {
        let e: [f64; 0] = [];
        assert_eq!(mse(&e, &e), 0.0);
        assert_eq!(r_squared(&e, &e), 1.0);
    }
}
