//! Statistics substrate: dense linear algebra, Pearson correlation,
//! multivariate polynomial least squares, segmented regression and the error
//! metrics of the paper's §4.1 (EQM/MSE, EAM/MAE, R², EAMP/MAPE).
//!
//! Everything is implemented from first principles (the offline environment
//! has no linear-algebra crates); the QR decomposition is Householder-based
//! and unit-tested against hand-computed systems.

pub mod linalg;
pub mod pearson;
pub mod polyfit;
pub mod segmented;
pub mod metrics;

pub use linalg::Mat;
pub use pearson::pearson;
pub use polyfit::{PolyModel, PolyTerm};
pub use segmented::SegmentedModel;
pub use metrics::{mae, mape, mse, r_squared, Metrics};
