//! Pearson product-moment correlation (the paper's §3.3 analysis).

/// Pearson correlation of two equal-length samples. Returns 0.0 when either
/// sample is constant (the paper reports exactly `0.000` for Conv3's
/// data-width column — a constant-resource sample, same convention).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "pearson: length mismatch");
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let mx = x.iter().sum::<f64>() / nf;
    let my = y.iter().sum::<f64>() / nf;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx < 1e-300 || syy < 1e-300 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())).clamp(-1.0, 1.0)
}

/// Pearson over integer samples (the resource counts are integers).
pub fn pearson_u64(x: &[u64], y: &[u64]) -> f64 {
    let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    pearson(&xf, &yf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_positive_and_negative() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_sample_gives_zero() {
        let x = [1.0, 2.0, 3.0];
        let y = [5.0, 5.0, 5.0];
        assert_eq!(pearson(&x, &y), 0.0);
        assert_eq!(pearson(&y, &x), 0.0);
    }

    #[test]
    fn known_value_hand_computed() {
        // x = [1,2,3], y = [1,2,4]: r = 0.9819805...
        let r = pearson(&[1.0, 2.0, 3.0], &[1.0, 2.0, 4.0]);
        assert!((r - 0.981_980_506_061_965_8).abs() < 1e-12, "{r}");
    }

    #[test]
    fn grid_sum_structure_matches_paper_magnitude() {
        // Over a 14x14 grid, y = d + c has corr ≈ 0.70 with each axis — the
        // magnitude the paper's Table 3 reports for the linear blocks.
        let mut d = Vec::new();
        let mut c = Vec::new();
        let mut y = Vec::new();
        for dv in 3..=16 {
            for cv in 3..=16 {
                d.push(dv as f64);
                c.push(cv as f64);
                y.push((dv + cv) as f64);
            }
        }
        let r = pearson(&d, &y);
        assert!((r - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-9, "{r}");
        assert!((pearson(&c, &y) - r).abs() < 1e-12);
    }

    #[test]
    fn uncorrelated_orthogonal_pattern() {
        let x = [1.0, 1.0, -1.0, -1.0];
        let y = [1.0, -1.0, 1.0, -1.0];
        assert!(pearson(&x, &y).abs() < 1e-12);
    }

    #[test]
    fn integer_wrapper() {
        assert!((pearson_u64(&[1, 2, 3], &[10, 20, 30]) - 1.0).abs() < 1e-12);
    }
}
