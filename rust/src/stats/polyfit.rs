//! Multivariate polynomial least-squares regression in the two block
//! parameters (data width `d`, coefficient width `c`).
//!
//! A degree-`g` model contains every monomial `d^i · c^j` with `i + j ≤ g`
//! (the paper fits degrees 1–4, §3.4). The fit also produces per-term
//! t-statistics from the coefficient covariance, which Algorithm 1's
//! `SupprimerInsignifiant` step uses to prune terms.

use crate::stats::linalg::Mat;
use crate::stats::metrics::r_squared;
use crate::util::error::{Error, Result};
use std::fmt;

/// One monomial term `coef · d^dx · c^cx`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolyTerm {
    /// Exponent of the data width.
    pub dx: u32,
    /// Exponent of the coefficient width.
    pub cx: u32,
    /// Fitted coefficient.
    pub coef: f64,
    /// |t|-statistic of this coefficient (0 when unavailable).
    pub t_stat: f64,
}

impl PolyTerm {
    fn basis(dx: u32, cx: u32) -> PolyTerm {
        PolyTerm { dx, cx, coef: 0.0, t_stat: 0.0 }
    }

    /// Evaluate the monomial at `(d, c)` (without the coefficient).
    pub fn monomial(&self, d: f64, c: f64) -> f64 {
        d.powi(self.dx as i32) * c.powi(self.cx as i32)
    }
}

/// A fitted polynomial model `y ≈ Σ coefᵢ · d^dxᵢ · c^cxᵢ`.
#[derive(Debug, Clone, PartialEq)]
pub struct PolyModel {
    /// Terms, in graded-lexicographic order.
    pub terms: Vec<PolyTerm>,
    /// Total degree requested at fit time.
    pub degree: u32,
    /// R² on the training data.
    pub r2: f64,
}

/// Graded-lex basis of total degree ≤ `g` in two variables.
pub fn basis_terms(g: u32) -> Vec<PolyTerm> {
    let mut t = Vec::new();
    for total in 0..=g {
        for dx in (0..=total).rev() {
            let cx = total - dx;
            t.push(PolyTerm::basis(dx, cx));
        }
    }
    t
}

impl PolyModel {
    /// Least-squares fit of a degree-`g` polynomial to `(d, c, y)` samples.
    pub fn fit(samples: &[(f64, f64, f64)], degree: u32) -> Result<PolyModel> {
        let terms = basis_terms(degree);
        Self::fit_terms(samples, &terms, degree)
    }

    /// Fit with an explicit term set (used after pruning).
    pub fn fit_terms(
        samples: &[(f64, f64, f64)],
        terms: &[PolyTerm],
        degree: u32,
    ) -> Result<PolyModel> {
        let n = samples.len();
        let k = terms.len();
        if n < k {
            return Err(Error::Numerical(format!(
                "{n} samples cannot identify {k} polynomial terms"
            )));
        }
        if k == 0 {
            return Err(Error::Numerical("empty term set".into()));
        }
        let mut x = Mat::zeros(n, k);
        let mut y = Vec::with_capacity(n);
        for (r, &(d, c, yy)) in samples.iter().enumerate() {
            for (j, t) in terms.iter().enumerate() {
                x[(r, j)] = t.monomial(d, c);
            }
            y.push(yy);
        }
        let beta = x.lstsq(&y)?;
        // Coefficient covariance: σ² (XᵀX)⁻¹ with σ² = SSR/(n-k).
        let preds = x.matvec(&beta);
        let ssr: f64 = y.iter().zip(&preds).map(|(a, b)| (a - b) * (a - b)).sum();
        let dof = (n - k).max(1) as f64;
        let sigma2 = ssr / dof;
        let tstats: Vec<f64> = match x.gram().inverse() {
            Ok(inv) => (0..k)
                .map(|j| {
                    let se = (sigma2 * inv[(j, j)]).sqrt();
                    if se < 1e-12 {
                        // Exact fits: a numerically-zero coefficient is
                        // insignificant even though its standard error is 0.
                        if beta[j].abs() < 1e-9 {
                            0.0
                        } else {
                            f64::INFINITY
                        }
                    } else {
                        (beta[j] / se).abs()
                    }
                })
                .collect(),
            Err(_) => vec![0.0; k],
        };
        let fitted: Vec<PolyTerm> = terms
            .iter()
            .zip(beta.iter().zip(&tstats))
            .map(|(t, (&coef, &ts))| PolyTerm { dx: t.dx, cx: t.cx, coef, t_stat: ts })
            .collect();
        let r2 = r_squared(&y, &preds);
        Ok(PolyModel { terms: fitted, degree, r2 })
    }

    /// Evaluate at `(d, c)`.
    pub fn eval(&self, d: f64, c: f64) -> f64 {
        self.terms.iter().map(|t| t.coef * t.monomial(d, c)).sum()
    }

    /// Number of terms.
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    /// True when the model has no terms.
    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Return the term set with all |t| < `threshold` terms removed (the
    /// intercept is always kept — dropping it degrades conditioning and the
    /// paper's closed forms all carry one).
    pub fn prune_terms(&self, threshold: f64) -> Vec<PolyTerm> {
        self.terms
            .iter()
            .filter(|t| (t.dx == 0 && t.cx == 0) || t.t_stat >= threshold)
            .map(|t| PolyTerm::basis(t.dx, t.cx))
            .collect()
    }

    /// Render as the paper's equation style, e.g.
    /// `20.886 + 1.004·d + 1.037·c`.
    pub fn equation(&self) -> String {
        let mut parts = Vec::new();
        for t in &self.terms {
            let var = match (t.dx, t.cx) {
                (0, 0) => String::new(),
                (1, 0) => "·d".into(),
                (0, 1) => "·c".into(),
                (i, 0) => format!("·d^{i}"),
                (0, j) => format!("·c^{j}"),
                (1, 1) => "·d·c".into(),
                (i, j) => format!("·d^{i}·c^{j}"),
            };
            parts.push(format!("{:.3}{var}", t.coef));
        }
        parts.join(" + ").replace("+ -", "- ")
    }
}

impl fmt::Display for PolyModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (deg {}, R²={:.3})", self.equation(), self.degree, self.r2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid<F: Fn(f64, f64) -> f64>(f: F) -> Vec<(f64, f64, f64)> {
        let mut s = Vec::new();
        for d in 3..=16 {
            for c in 3..=16 {
                s.push((d as f64, c as f64, f(d as f64, c as f64)));
            }
        }
        s
    }

    #[test]
    fn basis_sizes() {
        assert_eq!(basis_terms(1).len(), 3); // 1, d, c
        assert_eq!(basis_terms(2).len(), 6);
        assert_eq!(basis_terms(4).len(), 15);
    }

    #[test]
    fn recovers_exact_linear_form() {
        // The paper's Conv4 closed form.
        let s = grid(|d, c| 20.886 + 1.004 * d + 1.037 * c);
        let m = PolyModel::fit(&s, 1).unwrap();
        assert!((m.eval(8.0, 8.0) - (20.886 + 8.0 * (1.004 + 1.037))).abs() < 1e-9);
        assert!((m.r2 - 1.0).abs() < 1e-12);
        let eq = m.equation();
        assert!(eq.contains("20.886"), "{eq}");
        assert!(eq.contains("1.004·d"), "{eq}");
        assert!(eq.contains("1.037·c"), "{eq}");
    }

    #[test]
    fn recovers_bilinear_form_at_degree_two() {
        let s = grid(|d, c| 5.0 + 2.0 * d * c);
        let m1 = PolyModel::fit(&s, 1).unwrap();
        let m2 = PolyModel::fit(&s, 2).unwrap();
        assert!(m2.r2 > m1.r2);
        assert!((m2.r2 - 1.0).abs() < 1e-12);
        assert!((m2.eval(10.0, 12.0) - (5.0 + 240.0)).abs() < 1e-6);
    }

    #[test]
    fn tstats_flag_irrelevant_terms() {
        // y depends only on d; the c term should have a tiny t-stat once a
        // little *uncorrelated* noise is present.
        let mut s = grid(|d, _| 3.0 + 2.0 * d);
        let mut rng = crate::util::rng::SplitMix64::new(4242);
        for p in s.iter_mut() {
            p.2 += (rng.next_f64() - 0.5) * 0.02;
        }
        let m = PolyModel::fit(&s, 1).unwrap();
        let d_term = m.terms.iter().find(|t| t.dx == 1).unwrap();
        let c_term = m.terms.iter().find(|t| t.cx == 1).unwrap();
        assert!(d_term.t_stat > 100.0, "{}", d_term.t_stat);
        assert!(c_term.t_stat < 2.0, "{}", c_term.t_stat);
        // Pruning removes the c term, keeps intercept + d.
        let kept = m.prune_terms(2.0);
        assert_eq!(kept.len(), 2);
        assert!(kept.iter().any(|t| t.dx == 0 && t.cx == 0));
        assert!(kept.iter().any(|t| t.dx == 1 && t.cx == 0));
    }

    #[test]
    fn refit_after_prune_keeps_quality() {
        let s = grid(|d, _| 3.0 + 2.0 * d);
        let m = PolyModel::fit(&s, 2).unwrap();
        let kept = m.prune_terms(2.0);
        let m2 = PolyModel::fit_terms(&s, &kept, 2).unwrap();
        assert!(m2.r2 > 0.999);
        assert!(m2.len() < m.len());
    }

    #[test]
    fn rejects_underdetermined() {
        let s = vec![(1.0, 1.0, 1.0), (2.0, 2.0, 2.0)];
        assert!(PolyModel::fit(&s, 2).is_err());
    }

    #[test]
    fn equation_formats_negative_terms() {
        let s = grid(|d, c| 10.0 - 0.5 * d + 0.25 * c);
        let m = PolyModel::fit(&s, 1).unwrap();
        let eq = m.equation();
        assert!(eq.contains("- 0.500·d"), "{eq}");
    }
}
