//! Segmented (piecewise-linear) regression in one variable.
//!
//! Used for `Conv3`, whose resources are staircase functions of the
//! coefficient width alone (paper §3.4: "une régression segmentée pour
//! Conv3"; Table 4 reports an exact fit — R² = 1.00, EAMP = 0.00 — which a
//! piecewise model achieves because the staircase is deterministic).
//!
//! The fit is an exact dynamic program over breakpoint placements: for `n`
//! sorted distinct abscissae and at most `k` segments it minimizes total SSE
//! in O(n²·k), each segment being an ordinary least-squares line (or constant
//! when a segment holds a single x).

use crate::stats::metrics::r_squared;
use crate::util::error::{Error, Result};

/// One fitted segment over `x ∈ [lo, hi]` (inclusive): `y = a + b·x`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Segment lower bound (inclusive).
    pub lo: f64,
    /// Segment upper bound (inclusive).
    pub hi: f64,
    /// Intercept.
    pub a: f64,
    /// Slope.
    pub b: f64,
}

/// A piecewise-linear model over one variable.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentedModel {
    /// Segments in increasing-x order, contiguous, covering the fit range.
    pub segments: Vec<Segment>,
    /// R² on the training data.
    pub r2: f64,
}

fn line_fit(pts: &[(f64, f64)]) -> (f64, f64, f64) {
    // Returns (a, b, sse). Single-x groups degrade to a constant.
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let b = if sxx < 1e-12 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let sse: f64 = pts.iter().map(|p| (p.1 - a - b * p.0).powi(2)).sum();
    (a, b, sse)
}

impl SegmentedModel {
    /// Fit with at most `max_segments` segments. Points are grouped by
    /// distinct x (all y for one x belong to one segment).
    pub fn fit(points: &[(f64, f64)], max_segments: usize) -> Result<SegmentedModel> {
        if points.is_empty() {
            return Err(Error::Numerical("segmented fit of empty data".into()));
        }
        if max_segments == 0 {
            return Err(Error::Numerical("need at least one segment".into()));
        }
        // Group by distinct x, sorted.
        let mut pts = points.to_vec();
        pts.sort_by(|p, q| p.0.partial_cmp(&q.0).unwrap());
        let mut groups: Vec<Vec<(f64, f64)>> = Vec::new();
        for p in pts {
            match groups.last_mut() {
                Some(g) if (g[0].0 - p.0).abs() < 1e-12 => g.push(p),
                _ => groups.push(vec![p]),
            }
        }
        let n = groups.len();
        let k = max_segments.min(n);
        // cost[i][j] = SSE of one line over groups i..=j (precomputed).
        let mut cost = vec![vec![0.0f64; n]; n];
        let mut seg_ab = vec![vec![(0.0f64, 0.0f64); n]; n];
        for i in 0..n {
            for j in i..n {
                let flat: Vec<(f64, f64)> =
                    groups[i..=j].iter().flatten().copied().collect();
                let (a, b, sse) = line_fit(&flat);
                cost[i][j] = sse;
                seg_ab[i][j] = (a, b);
            }
        }
        // DP over number of segments.
        let inf = f64::INFINITY;
        let mut dp = vec![vec![inf; n + 1]; k + 1]; // dp[s][j] = best SSE for first j groups with s segments
        let mut back = vec![vec![0usize; n + 1]; k + 1];
        dp[0][0] = 0.0;
        for s in 1..=k {
            for j in 1..=n {
                for i in s - 1..j {
                    let cand = dp[s - 1][i] + cost[i][j - 1];
                    if cand < dp[s][j] {
                        dp[s][j] = cand;
                        back[s][j] = i;
                    }
                }
            }
        }
        // Pick the smallest segment count whose SSE is within 1e-9 of the
        // best achievable with k segments (parsimony), then reconstruct.
        let best_sse = dp[k][n];
        let mut s_used = k;
        for s in 1..=k {
            if dp[s][n] <= best_sse + 1e-9 {
                s_used = s;
                break;
            }
        }
        let mut bounds = Vec::new();
        let mut j = n;
        let mut s = s_used;
        while s > 0 {
            let i = back[s][j];
            bounds.push((i, j - 1));
            j = i;
            s -= 1;
        }
        bounds.reverse();
        let segments: Vec<Segment> = bounds
            .iter()
            .map(|&(i, j)| {
                let (a, b) = seg_ab[i][j];
                Segment { lo: groups[i][0].0, hi: groups[j][0].0, a, b }
            })
            .collect();
        // R² over the raw points.
        let model = SegmentedModel { segments, r2: 0.0 };
        let (yt, yp): (Vec<f64>, Vec<f64>) =
            points.iter().map(|&(x, y)| (y, model.eval(x))).unzip();
        let r2 = r_squared(&yt, &yp);
        Ok(SegmentedModel { r2, ..model })
    }

    /// Evaluate: x below/above the fit range clamps to the first/last segment.
    pub fn eval(&self, x: f64) -> f64 {
        let seg = self
            .segments
            .iter()
            .find(|s| x >= s.lo - 1e-12 && x <= s.hi + 1e-12)
            .unwrap_or_else(|| {
                if x < self.segments[0].lo {
                    &self.segments[0]
                } else {
                    self.segments.last().unwrap()
                }
            });
        seg.a + seg.b * x
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    /// True when no segments exist (cannot happen for a successful fit).
    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Human-readable description.
    pub fn describe(&self) -> String {
        self.segments
            .iter()
            .map(|s| format!("[{:.0},{:.0}]: {:.3}{:+.3}·c", s.lo, s.hi, s.a, s.b))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_staircase_fits_perfectly() {
        // A 3-level staircase like Conv3's correction logic.
        let pts: Vec<(f64, f64)> = (3..=16)
            .map(|c| {
                let y = if c <= 6 {
                    10.0
                } else if c <= 11 {
                    14.0
                } else {
                    19.0
                };
                (c as f64, y)
            })
            .collect();
        let m = SegmentedModel::fit(&pts, 6).unwrap();
        assert!((m.r2 - 1.0).abs() < 1e-12, "r2={}", m.r2);
        for &(x, y) in &pts {
            assert!((m.eval(x) - y).abs() < 1e-9);
        }
        assert!(m.len() <= 3, "parsimony: {} segments", m.len());
    }

    #[test]
    fn single_line_data_uses_one_segment() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 2.0 + 3.0 * i as f64)).collect();
        let m = SegmentedModel::fit(&pts, 4).unwrap();
        assert_eq!(m.len(), 1);
        assert!((m.segments[0].b - 3.0).abs() < 1e-9);
        assert!((m.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn two_slope_elbow() {
        // y = x for x<=5, y = 5 + 3(x-5) for x>5.
        let pts: Vec<(f64, f64)> = (0..=10)
            .map(|i| {
                let x = i as f64;
                (x, if x <= 5.0 { x } else { 5.0 + 3.0 * (x - 5.0) })
            })
            .collect();
        let m = SegmentedModel::fit(&pts, 3).unwrap();
        assert!((m.r2 - 1.0).abs() < 1e-12);
        assert!(m.len() == 2, "{}", m.describe());
        assert!((m.eval(2.0) - 2.0).abs() < 1e-9);
        assert!((m.eval(9.0) - 17.0).abs() < 1e-9);
    }

    #[test]
    fn eval_clamps_outside_range() {
        let pts: Vec<(f64, f64)> = (3..=6).map(|i| (i as f64, 7.0)).collect();
        let m = SegmentedModel::fit(&pts, 2).unwrap();
        assert!((m.eval(0.0) - 7.0).abs() < 1e-9);
        assert!((m.eval(100.0) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_x_points_grouped() {
        let pts = vec![(1.0, 2.0), (1.0, 2.0), (2.0, 4.0), (3.0, 6.0)];
        let m = SegmentedModel::fit(&pts, 3).unwrap();
        assert!((m.eval(2.0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_empty_and_zero_segments() {
        assert!(SegmentedModel::fit(&[], 2).is_err());
        assert!(SegmentedModel::fit(&[(1.0, 1.0)], 0).is_err());
    }
}
