//! Adder generators: carry-chain ripple adders and balanced adder trees.
//!
//! Synthesis inference rules modelled (Vivado `opt_design` equivalents are in
//! `mapper`):
//! * a `w`-bit add maps to `w` LUTs (the per-bit propagate/generate functions)
//!   feeding `ceil(w/8)` CARRY8 segments — UG574's standard mapping;
//! * adder trees are built balanced, widening by one bit per level, exactly as
//!   a synthesizer rebalances a 9-operand sum.

use crate::netlist::{Bus, Net, NetlistBuilder};

/// Result of elaborating an adder: the sum bus and its carry-out.
pub struct AdderOut {
    /// Sum bits (width = max(a, b) widths, plus one if `grow`).
    pub sum: Bus,
    /// Final carry-out net.
    pub cout: Net,
}

/// Elaborate a two-operand adder over buses `a` and `b` (widths may differ;
/// the narrower operand is implicitly sign-extended, which costs nothing in
/// LUTs because the extension bit reuses the MSB net). If `grow` is set the
/// sum is one bit wider than the widest input (no-overflow add).
pub fn add(b: &mut NetlistBuilder, label: &str, x: &[Net], y: &[Net], grow: bool) -> AdderOut {
    assert!(!x.is_empty() && !y.is_empty(), "adder with empty operand: {label}");
    b.push_scope(label);
    let w = x.len().max(y.len()) + usize::from(grow);
    // Per-bit P/G LUTs: each bit needs one LUT computing propagate (and the
    // carry chain derives generate from the DI input).
    let mut pg: Vec<Net> = Vec::with_capacity(2 * w);
    for i in 0..w {
        let xi = *x.get(i).unwrap_or(x.last().unwrap()); // sign-extend
        let yi = *y.get(i).unwrap_or(y.last().unwrap());
        // Shared static leaf: per-bit indices carried by the cell index in
        // reports/emission (perf: a format!() per bit dominated elaboration).
        let p = b.lut("pg", &[xi, yi]);
        // DI input of the chain takes one of the operands directly: no LUT.
        pg.push(p);
        pg.push(xi);
    }
    // Chain CARRY8 segments.
    let mut sum: Bus = Vec::with_capacity(w);
    let mut cin: Option<Net> = None;
    for (seg, chunk) in pg.chunks(16).enumerate() {
        let (s, co) = b.carry8(&format!("cc[{seg}]"), chunk, cin);
        let bits = chunk.len() / 2;
        sum.extend_from_slice(&s[..bits]);
        cin = Some(co);
    }
    b.pop_scope();
    AdderOut { sum, cout: cin.expect("at least one CARRY8") }
}

/// Registered adder: adds and registers the sum (pipelined accumulator stage).
pub fn add_reg(b: &mut NetlistBuilder, label: &str, x: &[Net], y: &[Net], grow: bool) -> Bus {
    let out = add(b, label, x, y, grow);
    b.push_scope(label);
    let q = b.fdre_bus("sum_reg", &out.sum);
    b.pop_scope();
    q
}

/// Balanced adder tree over `operands` (all buses, possibly different widths).
/// Each level pairs operands with growing width; the classic reduction a
/// synthesizer produces for `y = a0 + a1 + ... + an`.
pub fn adder_tree(b: &mut NetlistBuilder, label: &str, operands: &[Bus]) -> Bus {
    assert!(!operands.is_empty(), "adder tree needs operands: {label}");
    b.push_scope(label);
    let mut level: Vec<Bus> = operands.to_vec();
    let mut lvl = 0usize;
    while level.len() > 1 {
        let mut next: Vec<Bus> = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.chunks(2);
        let mut idx = 0usize;
        for pair in it.by_ref() {
            match pair {
                [a, c] => {
                    let out = add(b, &format!("l{lvl}_a{idx}"), a, c, true);
                    next.push(out.sum);
                }
                [a] => next.push(a.clone()),
                _ => unreachable!(),
            }
            idx += 1;
        }
        level = next;
        lvl += 1;
    }
    b.pop_scope();
    level.pop().unwrap()
}

/// Expected LUT cost of a two-operand `w`-bit add (used by sizing tests and
/// the analytical roofline in EXPERIMENTS.md).
pub fn adder_lut_cost(w: usize) -> u64 {
    w as u64
}

/// Expected CARRY8 cost of a `w`-bit add.
pub fn adder_cchain_cost(w: usize) -> u64 {
    w.div_ceil(8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::PrimitiveClass;

    fn count(b: NetlistBuilder) -> (u64, u64, u64) {
        let n = b.finish();
        n.validate().unwrap();
        let s = n.stats();
        (
            s.count(PrimitiveClass::LogicLut),
            s.count(PrimitiveClass::CarryChain),
            s.count(PrimitiveClass::FlipFlop),
        )
    }

    #[test]
    fn eight_bit_add_is_one_carry8() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(8);
        let y = b.top_input_bus(8);
        let out = add(&mut b, "a", &x, &y, false);
        assert_eq!(out.sum.len(), 8);
        let (lut, cc, _) = count(b);
        assert_eq!(lut, 8);
        assert_eq!(cc, 1);
    }

    #[test]
    fn nine_bit_add_spills_to_second_carry8() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(9);
        let y = b.top_input_bus(9);
        let _ = add(&mut b, "a", &x, &y, false);
        let (lut, cc, _) = count(b);
        assert_eq!(lut, 9);
        assert_eq!(cc, 2);
    }

    #[test]
    fn grow_widens_by_one() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(8);
        let y = b.top_input_bus(8);
        let out = add(&mut b, "a", &x, &y, true);
        assert_eq!(out.sum.len(), 9);
    }

    #[test]
    fn mixed_width_sign_extends() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(8);
        let y = b.top_input_bus(4);
        let out = add(&mut b, "a", &x, &y, false);
        assert_eq!(out.sum.len(), 8);
        let (lut, _, _) = count(b);
        assert_eq!(lut, 8, "extension reuses MSB net, still one LUT per bit");
    }

    #[test]
    fn add_reg_registers_full_width() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(8);
        let y = b.top_input_bus(8);
        let q = add_reg(&mut b, "a", &x, &y, true);
        assert_eq!(q.len(), 9);
        let (_, _, ff) = count(b);
        assert_eq!(ff, 9);
    }

    #[test]
    fn tree_of_nine_operands_has_eight_adds() {
        let mut b = NetlistBuilder::new("t");
        let ops: Vec<_> = (0..9).map(|_| b.top_input_bus(16)).collect();
        let sum = adder_tree(&mut b, "tree", &ops);
        // 9 operands -> 8 two-input adds; widths grow log2(9) ≈ 4 levels.
        assert!(sum.len() >= 16 + 4);
        let n = b.finish();
        n.validate().unwrap();
        // 8 adders, each >= 16 LUTs.
        assert!(n.stats().count(PrimitiveClass::LogicLut) >= 8 * 16);
    }

    #[test]
    fn tree_of_one_is_identity() {
        let mut b = NetlistBuilder::new("t");
        let ops = vec![b.top_input_bus(5)];
        let sum = adder_tree(&mut b, "tree", &ops);
        assert_eq!(sum.len(), 5);
        let n = b.finish();
        assert_eq!(n.stats().total_cells, 0);
    }

    #[test]
    fn cost_helpers_match_elaboration() {
        for w in [3usize, 8, 9, 16, 17, 24] {
            let mut b = NetlistBuilder::new("t");
            let x = b.top_input_bus(w);
            let y = b.top_input_bus(w);
            let _ = add(&mut b, "a", &x, &y, false);
            let n = b.finish();
            let s = n.stats();
            assert_eq!(s.count(PrimitiveClass::LogicLut), adder_lut_cost(w), "w={w}");
            assert_eq!(s.count(PrimitiveClass::CarryChain), adder_cchain_cost(w), "w={w}");
        }
    }
}
