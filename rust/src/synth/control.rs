//! Control-path generators: counters, one-hot FSMs and word muxes.
//!
//! Every convolution block carries a small control plane — a coefficient-load
//! bit counter (`ceil(log2(9·c))` bits), a tap/phase sequencer, and operand
//! muxes in the sequential datapaths. These contribute the *logarithmic* terms
//! in the resource polynomials: the reason the paper's degree-1 fits have
//! R² ≈ 0.99 instead of 1.0 (and why Table 4's residuals are nonzero) is
//! precisely these ceil/log staircase terms, which our generators reproduce
//! structurally.

use crate::netlist::{Bus, Net, NetlistBuilder};

/// Number of bits needed to count to `n` (inclusive): `ceil(log2(n+1))`.
pub fn count_bits(n: usize) -> usize {
    (usize::BITS - n.leading_zeros()) as usize
}

/// Binary up-counter with terminal-count detect. Costs `w` LUTs + `w` FFs for
/// the increment (toggle/carry-lookahead folded per bit into one LUT) plus
/// `ceil(w/6)` LUTs for the terminal-count comparator.
pub fn counter(b: &mut NetlistBuilder, label: &str, max: usize) -> (Bus, Net) {
    let w = count_bits(max).max(1);
    b.push_scope(label);
    let q: Bus = (0..w).map(|_| b.net()).collect();
    for i in 0..w {
        // Toggle logic for bit i folds the AND of lower bits (up to 5) + own
        // state into a single LUT6 for w<=6; wider counters chain through
        // extra LUTs (modelled by taking the 5 nearest lower bits — the
        // synthesizer's carry-lookahead tree has the same count).
        let lo = i.saturating_sub(4);
        let mut ins: Vec<Net> = q[lo..=i].to_vec();
        if ins.len() > 5 {
            ins.truncate(5);
        }
        let t = b.lut("inc", &ins);
        b.fdre_into("q", t, q[i]);
    }
    // Terminal count comparator over all w bits, 6 per LUT.
    let mut tc_parts: Vec<Net> = Vec::new();
    for chunk in q.chunks(6) {
        tc_parts.push(b.lut("tc", chunk));
    }
    let tc = if tc_parts.len() == 1 {
        tc_parts[0]
    } else {
        b.lut("tc_and", &tc_parts)
    };
    b.pop_scope();
    (q, tc)
}

/// One-hot FSM with `states` states: `states` FFs + one next-state LUT per
/// state (inputs: current state + up to 4 qualifiers).
pub fn fsm_one_hot(b: &mut NetlistBuilder, label: &str, states: usize, qualifiers: &[Net]) -> Bus {
    assert!(states >= 2, "FSM needs at least 2 states: {label}");
    b.push_scope(label);
    let q: Bus = (0..states).map(|_| b.net()).collect();
    for s in 0..states {
        let prev = q[(s + states - 1) % states];
        let mut ins = vec![prev, q[s]];
        ins.extend(qualifiers.iter().copied().take(4));
        let d = b.lut(&format!("ns[{s}]"), &ins);
        b.fdre_into(&format!("st[{s}]"), d, q[s]);
    }
    b.pop_scope();
    q
}

/// `n`-to-1 word mux over `w`-bit words: the synthesizer's tree of LUT6s —
/// each LUT6 selects between 2 words' bits per LUT? No: per output bit, a
/// `n`-to-1 mux costs `ceil((n-1)/2)` LUT6s (4:1 per LUT with 2 selects is
/// optimistic; Vivado's typical result is 2:1 per LUT with shared selects at
/// n≤4, captured here as `(n-1).div_ceil(2)` wide-input LUTs + MUXFs).
pub fn word_mux(b: &mut NetlistBuilder, label: &str, words: &[Bus], sel: &[Net]) -> Bus {
    assert!(words.len() >= 2, "mux needs at least 2 words: {label}");
    let w = words.iter().map(|b| b.len()).max().unwrap();
    b.push_scope(label);
    let mut out: Bus = Vec::with_capacity(w);
    for bit in 0..w {
        let mut level: Vec<Net> = words
            .iter()
            .map(|word| *word.get(bit).unwrap_or(word.last().unwrap()))
            .collect();
        let mut lvl = 0usize;
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            for (k, pair) in level.chunks(2).enumerate() {
                match pair {
                    [a, c] => {
                        let s = sel.get(lvl.min(sel.len().saturating_sub(1))).copied();
                        let mut ins = vec![*a, *c];
                        if let Some(sn) = s {
                            ins.push(sn);
                        }
                        next.push(b.lut(&format!("m{bit}_{lvl}_{k}"), &ins));
                    }
                    [a] => next.push(*a),
                    _ => unreachable!(),
                }
            }
            level = next;
            lvl += 1;
        }
        out.push(level[0]);
    }
    b.pop_scope();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{NetlistBuilder, PrimitiveClass};

    #[test]
    fn count_bits_staircase() {
        assert_eq!(count_bits(1), 1);
        assert_eq!(count_bits(2), 2);
        assert_eq!(count_bits(3), 2);
        assert_eq!(count_bits(4), 3);
        assert_eq!(count_bits(255), 8);
        assert_eq!(count_bits(256), 9);
    }

    #[test]
    fn counter_costs_follow_width() {
        let cost = |max: usize| {
            let mut b = NetlistBuilder::new("t");
            let _ = counter(&mut b, "c", max);
            let n = b.finish();
            n.validate().unwrap();
            (n.stats().count(PrimitiveClass::LogicLut), n.stats().count(PrimitiveClass::FlipFlop))
        };
        let (l27, f27) = cost(27); // 9 coeffs * 3 bits
        let (l144, f144) = cost(144); // 9 * 16
        assert_eq!(f27, 5);
        assert_eq!(f144, 8);
        assert!(l144 > l27);
    }

    #[test]
    fn counter_netlist_valid_with_feedback() {
        let mut b = NetlistBuilder::new("t");
        let (q, tc) = counter(&mut b, "c", 100);
        assert_eq!(q.len(), 7);
        let _ = tc;
        b.finish().validate().unwrap();
    }

    #[test]
    fn fsm_state_count() {
        let mut b = NetlistBuilder::new("t");
        let go = b.top_input();
        let st = fsm_one_hot(&mut b, "f", 4, &[go]);
        assert_eq!(st.len(), 4);
        let n = b.finish();
        n.validate().unwrap();
        assert_eq!(n.stats().count(PrimitiveClass::FlipFlop), 4);
        assert_eq!(n.stats().count(PrimitiveClass::LogicLut), 4);
    }

    #[test]
    fn word_mux_cost_scales_with_inputs_and_width() {
        let cost = |n: usize, w: usize| {
            let mut b = NetlistBuilder::new("t");
            let words: Vec<_> = (0..n).map(|_| b.top_input_bus(w)).collect();
            let sel = b.top_input_bus(count_bits(n - 1).max(1));
            let out = word_mux(&mut b, "m", &words, &sel);
            assert_eq!(out.len(), w);
            let nl = b.finish();
            nl.validate().unwrap();
            nl.stats().count(PrimitiveClass::LogicLut)
        };
        assert_eq!(cost(2, 8), 8);
        assert!(cost(9, 8) > cost(4, 8));
        assert!(cost(4, 16) == 2 * cost(4, 8));
    }
}
