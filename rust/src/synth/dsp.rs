//! DSP48E2 datapath generators.
//!
//! * [`dsp_mac`] — the plain inference for a sequential multiply-accumulate:
//!   one DSP48E2 in `A*B+P` mode, operands registered inside the slice (the
//!   A/B/P pipeline registers are *hard* registers — they cost no fabric FFs,
//!   which is why the paper measures `corr(FF, data width) = 0.000` for
//!   `Conv2`/`Conv4`: all data-width-dependent state lives inside the DSP).
//! * [`dsp_packed_mac`] — the INT8 two-lanes-in-one-DSP trick used by `Conv3`
//!   (Xilinx WP487): two 8-bit data lanes packed into the 27-bit A:D
//!   pre-adder path share one multiplier against a common coefficient; the
//!   cross-lane contamination is removed by a fabric *correction* stage whose
//!   size depends only on the coefficient width (one guard-fix LUT per
//!   coefficient bit pair + a step at each 4-bit alignment boundary) — the
//!   structural origin of the paper's segmented `Conv3` model and its
//!   `corr(LLUT, data width) = 0.000` row.

use crate::netlist::{Bus, Net, NetlistBuilder};

/// Plain DSP MAC: multiplies `a` (≤27b) by `b_port` (≤18b), accumulating in P.
/// Returns the P bus. No fabric cost besides the slice itself.
pub fn dsp_mac(b: &mut NetlistBuilder, label: &str, a: &[Net], b_port: &[Net]) -> Bus {
    assert!(a.len() <= 27 && b_port.len() <= 18, "dsp_mac port widths: {label}");
    b.dsp48e2(label, a, b_port, &[], &[])
}

/// Packed dual-lane DSP MAC (the WP487 INT8 trick).
///
/// `lane0` and `lane1` are the two data operands (each ≤ 8 bits — the packing
/// headroom of the 27-bit port with guard bits); `coeff` is the shared
/// coefficient (≤ 18-c bits of headroom). Fabric cost:
///   * lane packing: `lane1` is shifted into the high half of A via the D-port
///     pre-adder — free;
///   * sign-guard preparation: 2 LUTs (lane-1 sign into the guard band);
///   * correction stage: the high product lane accumulates `lane0`'s sign
///     extension crossed with the coefficient; repairing it costs
///     `2 + ceil(c/2)` LUTs plus one extra LUT at each 4-bit boundary of `c`
///     (the guard-bit carry look-ahead splits there), i.e. a *staircase in c*,
///     independent of the data width.
///
/// Returns (lane0 product bus, lane1 product bus).
pub fn dsp_packed_mac(
    b: &mut NetlistBuilder,
    label: &str,
    lane0: &[Net],
    lane1: &[Net],
    coeff: &[Net],
) -> (Bus, Bus) {
    assert!(lane0.len() <= 8 && lane1.len() <= 8, "packed lanes are ≤ 8 bits: {label}");
    let c = coeff.len();
    b.push_scope(label);
    // Guard preparation: 2 LUTs folding lane-1 sign into the guard band.
    let g0 = b.lut("guard0", &[*lane1.last().unwrap()]);
    let g1 = b.lut("guard1", &[*lane1.last().unwrap(), *lane0.last().unwrap()]);
    // The packed A:D operand: 8 (lane0) + 2 guard + 8 (lane1) ≤ 27 bits.
    let mut packed: Vec<Net> = Vec::with_capacity(18);
    packed.extend_from_slice(lane0);
    packed.push(g0);
    packed.push(g1);
    packed.extend_from_slice(lane1);
    let p = b.dsp48e2("slice", &packed, coeff, &[], &[]);
    // Correction stage for the high lane: a byte-lane staircase in the
    // coefficient width — one 4-LUT borrow-fix group per 8-bit coefficient
    // lane (the INT8 boundary: beyond 8 bits the product tail crosses into a
    // second byte lane and needs a second fix group). This is the coarse
    // step the paper's segmented Conv3 model captures (corr ≈ 0.5 with c).
    let n_fix = 4 + 4 * c.div_ceil(8);
    let mut hi_fixed: Bus = Vec::new();
    for k in 0..n_fix {
        let i0 = 16 + (k % 16);
        let fix = b.lut("fix", &[p[i0], p[(i0 + 1).min(47)], g1]);
        hi_fixed.push(fix);
    }
    // Lane extraction: low lane is P[0..8+c], high lane is the fixed bits plus
    // raw P tail.
    let lo: Bus = p[..(8 + c).min(16)].to_vec();
    b.pop_scope();
    (lo, hi_fixed)
}

/// Analytical LLUT cost of the packed-MAC correction stage (must stay in sync
/// with `dsp_packed_mac`; checked by a test). A byte-lane staircase in `c`.
pub fn packed_correction_luts(c: usize) -> u64 {
    (2 + 4 + 4 * c.div_ceil(8)) as u64 // 2 guard + fix groups per byte lane
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{NetlistBuilder, PrimitiveClass};

    #[test]
    fn dsp_mac_costs_one_slice_no_fabric() {
        let mut b = NetlistBuilder::new("t");
        let a = b.top_input_bus(16);
        let bb = b.top_input_bus(16);
        let p = dsp_mac(&mut b, "m", &a, &bb);
        assert_eq!(p.len(), 48);
        let n = b.finish();
        n.validate().unwrap();
        assert_eq!(n.stats().count(PrimitiveClass::Dsp), 1);
        assert_eq!(n.stats().count(PrimitiveClass::LogicLut), 0);
        assert_eq!(n.stats().count(PrimitiveClass::FlipFlop), 0);
    }

    #[test]
    fn packed_mac_cost_independent_of_data_width() {
        let cost = |d: usize, c: usize| {
            let mut b = NetlistBuilder::new("t");
            let l0 = b.top_input_bus(d);
            let l1 = b.top_input_bus(d);
            let co = b.top_input_bus(c);
            let _ = dsp_packed_mac(&mut b, "pm", &l0, &l1, &co);
            let n = b.finish();
            n.validate().unwrap();
            n.stats().count(PrimitiveClass::LogicLut)
        };
        assert_eq!(cost(3, 8), cost(8, 8), "LLUT must not depend on lane width");
        assert_eq!(cost(4, 11), cost(7, 11));
    }

    #[test]
    fn packed_mac_staircase_in_coeff_width() {
        let cost = |c: usize| {
            let mut b = NetlistBuilder::new("t");
            let l0 = b.top_input_bus(8);
            let l1 = b.top_input_bus(8);
            let co = b.top_input_bus(c);
            let _ = dsp_packed_mac(&mut b, "pm", &l0, &l1, &co);
            b.finish().stats().count(PrimitiveClass::LogicLut)
        };
        // Monotone staircase: flat on some steps, jumps on others.
        let costs: Vec<u64> = (3..=16).map(cost).collect();
        assert!(costs.windows(2).all(|w| w[0] <= w[1]), "monotone: {costs:?}");
        assert!(costs.windows(2).any(|w| w[0] == w[1]), "has flats: {costs:?}");
        assert!(costs.windows(2).any(|w| w[0] < w[1]), "has jumps: {costs:?}");
        // Matches the analytical formula used by the segmented-model tests.
        for (i, c) in (3..=16).enumerate() {
            assert_eq!(costs[i], packed_correction_luts(c), "c={c}");
        }
    }

    #[test]
    fn packed_mac_uses_single_dsp() {
        let mut b = NetlistBuilder::new("t");
        let l0 = b.top_input_bus(8);
        let l1 = b.top_input_bus(8);
        let co = b.top_input_bus(8);
        let (lo, hi) = dsp_packed_mac(&mut b, "pm", &l0, &l1, &co);
        assert!(!lo.is_empty() && !hi.is_empty());
        let n = b.finish();
        assert_eq!(n.stats().count(PrimitiveClass::Dsp), 1);
    }

    #[test]
    #[should_panic(expected = "packed lanes")]
    fn packed_mac_rejects_wide_lanes() {
        let mut b = NetlistBuilder::new("t");
        let l0 = b.top_input_bus(9);
        let l1 = b.top_input_bus(8);
        let co = b.top_input_bus(8);
        let _ = dsp_packed_mac(&mut b, "pm", &l0, &l1, &co);
    }
}
