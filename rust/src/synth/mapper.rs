//! Technology mapping: netlist → utilization report.
//!
//! Models the two post-elaboration effects that separate a naive primitive
//! count from what Vivado's utilization report shows:
//!
//! 1. **LUT packing / remapping** (`opt_design` + the mapper's LUT6_2 dual
//!    output packing): pairs of small functions (≤ 3 used inputs) that share a
//!    fanin neighbourhood are packed two-per-LUT; larger functions map 1:1.
//!    We model the pairing success rate at 85 % of eligible pairs — measured
//!    packing rates for control-dominated designs on UltraScale+ fall in the
//!    0.8–0.9 band (UG904's examples).
//! 2. **Optimizer variability**: placement-seed-dependent replication/rewiring
//!    makes repeated Vivado runs of the same RTL differ by a few LUTs/FFs.
//!    We emulate it with a deterministic per-design jitter (hash-seeded,
//!    ±≈1.5 % Gaussian on LLUT and FF, clamped at ±4 %) so that the fitted
//!    models face realistic residuals (paper Table 4 reports MAPE 0–3 %).
//!    Structural resources (MLUT, CARRY8, DSP) are exact — a carry chain or a
//!    DSP is never split by the optimizer.

use crate::netlist::{Netlist, Primitive, PrimitiveClass};
use crate::synth::ResourceVector;
use crate::util::hashing::stable_seed;
use crate::util::rng::SplitMix64;

/// Mapper knobs (defaults reproduce the calibrated pipeline; tests and the
/// `--no-jitter` CLI flag use the exact variant).
#[derive(Debug, Clone)]
pub struct MapOptions {
    /// Fraction of eligible small-LUT pairs successfully packed (0..=1).
    pub pack_rate: f64,
    /// Standard deviation of the multiplicative jitter on LLUT/FF.
    pub jitter_sigma: f64,
    /// Hard clamp on the jitter magnitude.
    pub jitter_clamp: f64,
    /// Master seed mixed into each design's private jitter stream.
    pub seed: u64,
}

impl Default for MapOptions {
    fn default() -> Self {
        MapOptions { pack_rate: 0.85, jitter_sigma: 0.015, jitter_clamp: 0.04, seed: 0x5EED_CAFE }
    }
}

impl MapOptions {
    /// Exact mapping: packing still applies (it is deterministic), jitter off.
    pub fn exact() -> Self {
        MapOptions { jitter_sigma: 0.0, jitter_clamp: 0.0, ..Default::default() }
    }
}

/// Map a netlist to its utilization vector.
pub fn map_netlist(n: &Netlist, opts: &MapOptions) -> ResourceVector {
    // --- raw structural counts ---
    let mut small_luts = 0u64; // ≤3 used inputs: packing candidates
    let mut big_luts = 0u64;
    let mut mlut = 0u64;
    let mut ff = 0u64;
    let mut cc_short = 0u64; // CARRY8 segments using ≤4 bits
    let mut cc_long = 0u64;
    let mut dsp = 0u64;
    for cell in &n.cells {
        match cell.prim {
            Primitive::Lut { inputs } => {
                if inputs <= 3 {
                    small_luts += 1;
                } else {
                    big_luts += 1;
                }
            }
            Primitive::Carry8 => {
                // P/G pairs occupy 2 inputs each (plus an optional carry-in).
                let bits = cell.inputs.len() / 2;
                if bits <= 4 {
                    cc_short += 1;
                } else {
                    cc_long += 1;
                }
            }
            _ => match cell.prim.class() {
                PrimitiveClass::MemoryLut => mlut += cell.prim.lut_cost() as u64,
                PrimitiveClass::FlipFlop => ff += 1,
                PrimitiveClass::Dsp => dsp += 1,
                _ => {}
            },
        }
    }

    // --- LUT packing ---
    // Eligible pairs: floor(small/2); each packed pair saves one LUT site.
    let pairs = small_luts / 2;
    let packed = (pairs as f64 * opts.pack_rate).floor() as u64;
    let llut_exact = big_luts + small_luts - packed;

    // --- carry packing ---
    // UltraScale+ CARRY8 runs as two independent 4-bit chains (CI / CI_TOP),
    // so pairs of ≤4-bit segments share one primitive. Deterministic (a
    // placement guarantee, not a heuristic), which preserves the exact
    // Conv3-style structural counts.
    let cchain = cc_long + cc_short - cc_short / 2;

    // --- optimizer jitter (deterministic per *structure*) ---
    // Seeded from a structural fingerprint, NOT the design name: Vivado is
    // deterministic — identical netlists produce identical reports — and the
    // paper's exact `corr = 0.000` rows (Conv3 vs data width) depend on that.
    let (llut, ff) = if opts.jitter_sigma > 0.0 {
        let seed = stable_seed(
            "map",
            &[
                opts.seed,
                llut_exact,
                ff,
                mlut,
                cchain,
                dsp,
                n.net_count as u64,
                n.cells.len() as u64,
            ],
        );
        let mut rng = SplitMix64::new(seed);
        let jit = |rng: &mut SplitMix64, v: u64, sigma: f64, clamp: f64| -> u64 {
            if v == 0 {
                return 0;
            }
            let f = (rng.next_gaussian() * sigma).clamp(-clamp, clamp);
            ((v as f64) * (1.0 + f)).round().max(0.0) as u64
        };
        (
            jit(&mut rng, llut_exact, opts.jitter_sigma, opts.jitter_clamp),
            jit(&mut rng, ff, opts.jitter_sigma, opts.jitter_clamp),
        )
    } else {
        (llut_exact, ff)
    };

    ResourceVector { llut, mlut, ff, cchain, dsp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::NetlistBuilder;

    fn control_heavy(name: &str) -> Netlist {
        // 400 two-input LUTs (packable), 100 five-input LUTs, 200 FFs —
        // large enough that the ±1.5% jitter doesn't quantize away.
        let mut b = NetlistBuilder::new(name);
        let x = b.top_input_bus(6);
        for i in 0..400 {
            let y = b.lut(&format!("s{i}"), &[x[0], x[1]]);
            if i < 200 {
                b.fdre(&format!("r{i}"), y);
            }
        }
        for i in 0..100 {
            b.lut(&format!("w{i}"), &x[..5]);
        }
        b.finish()
    }

    #[test]
    fn packing_reduces_small_luts() {
        let n = control_heavy("pk");
        let exact = map_netlist(&n, &MapOptions::exact());
        // 400 small -> 200 pairs -> 170 packed (85%): 400-170+100 = 330.
        assert_eq!(exact.llut, 330);
        assert_eq!(exact.ff, 200);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let n = control_heavy("jt");
        let a = map_netlist(&n, &MapOptions::default());
        let b2 = map_netlist(&n, &MapOptions::default());
        assert_eq!(a, b2, "same design + seed => same report");
        let exact = map_netlist(&n, &MapOptions::exact());
        let rel = (a.llut as f64 - exact.llut as f64).abs() / exact.llut as f64;
        assert!(rel <= 0.041, "jitter beyond clamp: {rel}");
        // Structural resources never jitter.
        assert_eq!(a.mlut, exact.mlut);
        assert_eq!(a.cchain, exact.cchain);
        assert_eq!(a.dsp, exact.dsp);
    }

    #[test]
    fn jitter_identical_for_identical_structures() {
        // Vivado determinism: same netlist (regardless of its name) must map
        // to the same report — the paper's exact `corr = 0.000` rows for
        // Conv3 depend on this.
        let a = map_netlist(&control_heavy("da"), &MapOptions::default());
        let b2 = map_netlist(&control_heavy("db"), &MapOptions::default());
        assert_eq!(a, b2);
    }

    #[test]
    fn jitter_differs_across_structures() {
        let a = map_netlist(&control_heavy("s"), &MapOptions::default());
        // Add one LUT: different structure, different jitter stream.
        let mut b = NetlistBuilder::new("s");
        let x = b.top_input_bus(6);
        for i in 0..400 {
            let y = b.lut(&format!("s{i}"), &[x[0], x[1]]);
            if i < 200 {
                b.fdre(&format!("r{i}"), y);
            }
        }
        for i in 0..101 {
            b.lut(&format!("w{i}"), &x[..5]);
        }
        let c = map_netlist(&b.finish(), &MapOptions::default());
        assert_ne!(a, c);
    }

    #[test]
    fn seed_changes_jitter() {
        let n = control_heavy("sd");
        let a = map_netlist(&n, &MapOptions::default());
        let b2 = map_netlist(&n, &MapOptions { seed: 999, ..Default::default() });
        assert!(a.llut != b2.llut || a.ff != b2.ff);
    }

    #[test]
    fn empty_netlist_maps_to_zero() {
        let n = NetlistBuilder::new("e").finish();
        assert_eq!(map_netlist(&n, &MapOptions::default()), ResourceVector::default());
    }

    #[test]
    fn dsp_and_carry_counted_exact() {
        let mut b = NetlistBuilder::new("t");
        let a = b.top_input_bus(8);
        let c = b.top_input_bus(8);
        b.dsp48e2("d", &a, &c, &[], &[]);
        let pg: Vec<_> = (0..16).map(|_| b.top_input()).collect();
        b.carry8("cc", &pg, None);
        let n = b.finish();
        let v = map_netlist(&n, &MapOptions::default());
        assert_eq!(v.dsp, 1);
        assert_eq!(v.cchain, 1);
    }
}
