//! The synthesis simulator: RTL-level structure generators + an UltraScale+
//! technology mapper.
//!
//! This module substitutes for Vivado 2024.2 in the paper's methodology
//! (DESIGN.md §2). Generators ([`adder`], [`multiplier`], [`storage`],
//! [`control`], [`dsp`]) elaborate word-level structures into
//! [`crate::netlist`] primitives exactly the way a synthesizer's inference
//! engine would (carry chains for adds, SRLs for serial stores, DSP48E2 for
//! MACs). The [`mapper`] then applies LUT packing and a deterministic
//! per-design optimizer jitter, producing the [`ResourceVector`] a Vivado
//! utilization report would show.

pub mod adder;
pub mod multiplier;
pub mod storage;
pub mod control;
pub mod dsp;
pub mod mapper;
pub mod timing;

pub use mapper::{map_netlist, MapOptions};

use std::fmt;
use std::ops::{Add, AddAssign};

/// The five resources the paper measures, as one utilization vector.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResourceVector {
    /// LUTs used as combinational logic.
    pub llut: u64,
    /// LUTs used as memory (SRL, distributed RAM) in LUT-site units.
    pub mlut: u64,
    /// Flip-flops.
    pub ff: u64,
    /// CARRY8 carry-chain segments.
    pub cchain: u64,
    /// DSP48E2 slices.
    pub dsp: u64,
}

impl ResourceVector {
    /// Construct from explicit counts.
    pub fn new(llut: u64, mlut: u64, ff: u64, cchain: u64, dsp: u64) -> Self {
        ResourceVector { llut, mlut, ff, cchain, dsp }
    }

    /// Component access by the paper's resource name.
    pub fn get(&self, resource: Resource) -> u64 {
        match resource {
            Resource::Llut => self.llut,
            Resource::Mlut => self.mlut,
            Resource::Ff => self.ff,
            Resource::CChain => self.cchain,
            Resource::Dsp => self.dsp,
        }
    }

    /// Scale by an integer block count (allocation studies).
    pub fn scaled(&self, n: u64) -> ResourceVector {
        ResourceVector {
            llut: self.llut * n,
            mlut: self.mlut * n,
            ff: self.ff * n,
            cchain: self.cchain * n,
            dsp: self.dsp * n,
        }
    }

    /// True iff every component of `self` fits within `budget`.
    pub fn fits_within(&self, budget: &ResourceVector) -> bool {
        self.llut <= budget.llut
            && self.mlut <= budget.mlut
            && self.ff <= budget.ff
            && self.cchain <= budget.cchain
            && self.dsp <= budget.dsp
    }
}

impl Add for ResourceVector {
    type Output = ResourceVector;
    fn add(self, o: ResourceVector) -> ResourceVector {
        ResourceVector {
            llut: self.llut + o.llut,
            mlut: self.mlut + o.mlut,
            ff: self.ff + o.ff,
            cchain: self.cchain + o.cchain,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for ResourceVector {
    fn add_assign(&mut self, o: ResourceVector) {
        *self = *self + o;
    }
}

impl fmt::Display for ResourceVector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "LLUT={} MLUT={} FF={} CChain={} DSP={}",
            self.llut, self.mlut, self.ff, self.cchain, self.dsp
        )
    }
}

/// The paper's measured resource kinds (column order of its tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Resource {
    Llut,
    Mlut,
    Ff,
    CChain,
    Dsp,
}

impl Resource {
    /// All resources in the paper's reporting order.
    pub const ALL: [Resource; 5] =
        [Resource::Llut, Resource::Mlut, Resource::Ff, Resource::CChain, Resource::Dsp];

    /// Paper-facing name.
    pub fn name(&self) -> &'static str {
        match self {
            Resource::Llut => "LLUT",
            Resource::Mlut => "MLUT",
            Resource::Ff => "FF",
            Resource::CChain => "CChain",
            Resource::Dsp => "DSP",
        }
    }

    /// Parse a paper-facing name (case-insensitive).
    pub fn parse(s: &str) -> Option<Resource> {
        match s.to_ascii_lowercase().as_str() {
            "llut" | "lut" => Some(Resource::Llut),
            "mlut" => Some(Resource::Mlut),
            "ff" => Some(Resource::Ff),
            "cchain" | "carry" | "carry8" => Some(Resource::CChain),
            "dsp" => Some(Resource::Dsp),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_algebra() {
        let a = ResourceVector::new(1, 2, 3, 4, 5);
        let b = ResourceVector::new(10, 20, 30, 40, 50);
        assert_eq!(a + b, ResourceVector::new(11, 22, 33, 44, 55));
        assert_eq!(a.scaled(3), ResourceVector::new(3, 6, 9, 12, 15));
        let mut c = a;
        c += b;
        assert_eq!(c, a + b);
    }

    #[test]
    fn fits_within_is_componentwise() {
        let budget = ResourceVector::new(10, 10, 10, 10, 10);
        assert!(ResourceVector::new(10, 0, 0, 0, 0).fits_within(&budget));
        assert!(!ResourceVector::new(11, 0, 0, 0, 0).fits_within(&budget));
        assert!(!ResourceVector::new(0, 0, 0, 0, 11).fits_within(&budget));
    }

    #[test]
    fn resource_names_roundtrip() {
        for r in Resource::ALL {
            assert_eq!(Resource::parse(r.name()), Some(r));
        }
        assert_eq!(Resource::parse("carry8"), Some(Resource::CChain));
        assert_eq!(Resource::parse("bogus"), None);
    }

    #[test]
    fn get_matches_fields() {
        let v = ResourceVector::new(1, 2, 3, 4, 5);
        assert_eq!(v.get(Resource::Llut), 1);
        assert_eq!(v.get(Resource::Mlut), 2);
        assert_eq!(v.get(Resource::Ff), 3);
        assert_eq!(v.get(Resource::CChain), 4);
        assert_eq!(v.get(Resource::Dsp), 5);
    }

    #[test]
    fn display_contains_all_fields() {
        let s = ResourceVector::new(1, 2, 3, 4, 5).to_string();
        for part in ["LLUT=1", "MLUT=2", "FF=3", "CChain=4", "DSP=5"] {
            assert!(s.contains(part));
        }
    }
}
