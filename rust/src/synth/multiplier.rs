//! Multiplier generators.
//!
//! Two fabric multipliers are modelled, matching the two datapath styles the
//! paper's `Conv1` design space cares about:
//!
//! * [`array_multiplier`] — the fully combinational Baugh-Wooley array a
//!   synthesizer infers for `a * b` when DSPs are excluded: `c` partial-product
//!   rows of AND LUTs reduced by a carry-chain adder ladder. Cost ~ `d·c` LUTs.
//! * [`bit_serial_mac`] — the coefficient-bit-serial multiply-accumulate used
//!   by our `Conv1` (DESIGN.md §4): per tap, one add-shift stage of `d+1` bits
//!   that consumes one coefficient bit per cycle, with the partial sum in
//!   flip-flops and the shifted-out product tail in an SRL. Cost ~ `d` LUTs per
//!   tap, independent of `c` in logic, `c`-dependent only in the SRL depth —
//!   exactly the structure that keeps `Conv1` at ~100 LUTs where an array
//!   version would cost ~650 (this trade is the paper's Table 2 "Logique et
//!   CChains" row).

use crate::netlist::{Bus, NetlistBuilder};
use crate::synth::adder;

/// Fully combinational signed array multiplier: `x` (d bits) × `y` (c bits)
/// → d+c-bit product bus.
pub fn array_multiplier(b: &mut NetlistBuilder, label: &str, x: &[Net], y: &[Net]) -> Bus {
    // Synthesizers use the NARROWER operand as the multiplier (fewer partial
    // product rows, shorter ladder) — keeping the cost surface symmetric in
    // the two widths, which is exactly what the paper's near-equal Conv1
    // correlations (0.668 / 0.672) reflect.
    let (x, y) = if x.len() < y.len() { (y, x) } else { (x, y) };
    let d = x.len();
    let c = y.len();
    assert!(d >= 1 && c >= 1, "array multiplier needs operands: {label}");
    b.push_scope(label);
    // Partial products: one AND LUT per (i, j). (Baugh-Wooley sign handling
    // folds into the same LUT as the complement terms.)
    let mut rows: Vec<Bus> = Vec::with_capacity(c);
    for j in 0..c {
        let mut row: Bus = Vec::with_capacity(d + j);
        for i in 0..d {
            // Static leaf (perf): bit identity lives in the cell index.
            row.push(b.lut("pp", &[x[i], y[j]]));
        }
        // Weight 2^j: the shift itself is resource-free routing, but it widens
        // every adder below it. Model the alignment by padding the row to
        // d + j bits with (free) copies of its top bit — the adder ladder then
        // naturally grows to the true partial-sum widths.
        let msb = *row.last().unwrap();
        row.extend(std::iter::repeat(msb).take(j));
        rows.push(row);
    }
    // Reduction ladder: rows are accumulated pairwise (balanced tree), the
    // standard inference for a partial-product sum.
    let product = adder::adder_tree(b, "ladder", &rows);
    b.pop_scope();
    // Product width: d + c bits (tree may produce a few more due to balanced
    // growth; truncate to the arithmetically exact width).
    let mut p = product;
    p.truncate(d + c);
    p
}

use crate::netlist::Net;

/// Output of a bit-serial MAC tap.
pub struct SerialMacOut {
    /// Partial-sum register outputs (d+1 bits, the add-shift stage).
    pub psum: Bus,
    /// Product tail shift-register output (serial, one net).
    pub tail: Net,
}

/// Coefficient-bit-serial multiply-accumulate tap.
///
/// Processes one coefficient bit per cycle (LSB first over `c` cycles): each
/// cycle the `d`-bit data word is conditionally added (AND with the current
/// coefficient bit — folded into the adder's P/G LUT for free) to the running
/// partial sum, whose LSB shifts out into an SRL that assembles the product
/// tail. Hardware per tap:
///   * `d+1` LUTs + `ceil((d+1)/8)` CARRY8 (the add-shift),
///   * `d+1` FDRE (partial-sum register),
///   * `ceil(c/16)` SRL16 (product tail).
pub fn bit_serial_mac(
    b: &mut NetlistBuilder,
    label: &str,
    data: &[Net],
    coeff_bit: Net,
    c_bits: usize,
) -> SerialMacOut {
    let d = data.len();
    assert!(d >= 1 && c_bits >= 1, "serial MAC needs widths: {label}");
    b.push_scope(label);
    // Gated operand: the AND with coeff_bit folds into the P/G LUT of the
    // adder (3-input LUT instead of 2-input: same LUT count). Model that by
    // building the adder over a virtual operand of LUTs with 3 inputs.
    let w = d + 1;
    let mut psum_d: Bus = Vec::with_capacity(w);
    // Feedback nets for the partial-sum register (allocated first so the adder
    // LUTs can reference them).
    let psum_q: Bus = (0..w).map(|_| b.net()).collect();
    let mut pg: Vec<Net> = Vec::with_capacity(2 * w);
    for i in 0..w {
        let xi = *data.get(i).unwrap_or(data.last().unwrap());
        // P/G LUT folds: data bit, coeff enable, feedback sum bit.
        let p = b.lut(&format!("pg[{i}]"), &[xi, coeff_bit, psum_q[i]]);
        pg.push(p);
        pg.push(psum_q[i]);
    }
    let mut cin: Option<Net> = None;
    for (seg, chunk) in pg.chunks(16).enumerate() {
        let (s, co) = b.carry8(&format!("cc[{seg}]"), chunk, cin);
        psum_d.extend_from_slice(&s[..chunk.len() / 2]);
        cin = Some(co);
    }
    // Partial-sum register: note the register *drives* the feedback nets
    // allocated above; structurally we insert FDREs whose outputs are the
    // psum_q nets. Builder FDREs allocate fresh outputs, so wire via 1-LUT
    // "route-through" would be wasteful; instead add the FDREs manually.
    for i in 0..w {
        b.fdre_into(&format!("psum[{i}]"), psum_d[i], psum_q[i]);
    }
    // Product tail SRL(s): depth c, one bit wide.
    let mut tail = psum_d[0];
    for k in 0..c_bits.div_ceil(16) {
        tail = b.srl16(&format!("tail[{k}]"), tail, coeff_bit);
    }
    b.pop_scope();
    SerialMacOut { psum: psum_q, tail }
}

/// Analytical cost of one serial MAC tap (sizing tests + EXPERIMENTS roofline).
pub fn serial_mac_costs(d: usize, c: usize) -> (u64, u64, u64, u64) {
    let w = d + 1;
    let lut = w as u64;
    let cchain = w.div_ceil(8) as u64;
    let ff = w as u64;
    let mlut = c.div_ceil(16) as u64;
    (lut, cchain, ff, mlut)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{NetlistBuilder, PrimitiveClass};

    #[test]
    fn array_multiplier_cost_scales_with_d_times_c() {
        let mut costs = Vec::new();
        for (d, c) in [(4usize, 4usize), (8, 8), (16, 16)] {
            let mut b = NetlistBuilder::new("t");
            let x = b.top_input_bus(d);
            let y = b.top_input_bus(c);
            let p = array_multiplier(&mut b, "m", &x, &y);
            assert_eq!(p.len(), d + c);
            let n = b.finish();
            n.validate().unwrap();
            costs.push(n.stats().count(PrimitiveClass::LogicLut));
        }
        // Quadratic growth: 16x16 should be ~4x of 8x8, well over 2x.
        assert!(costs[2] > costs[1] * 3);
        assert!(costs[1] > costs[0] * 3);
        // Partial products alone are d*c.
        assert!(costs[1] >= 64);
    }

    #[test]
    fn serial_mac_matches_analytical_costs() {
        for (d, c) in [(3usize, 3usize), (8, 8), (8, 16), (16, 5), (16, 16)] {
            let mut b = NetlistBuilder::new("t");
            let x = b.top_input_bus(d);
            let cb = b.top_input();
            let _ = bit_serial_mac(&mut b, "tap", &x, cb, c);
            let n = b.finish();
            n.validate().unwrap();
            let s = n.stats();
            let (lut, cc, ff, mlut) = serial_mac_costs(d, c);
            assert_eq!(s.count(PrimitiveClass::LogicLut), lut, "lut d={d} c={c}");
            assert_eq!(s.count(PrimitiveClass::CarryChain), cc, "cc d={d} c={c}");
            assert_eq!(s.count(PrimitiveClass::FlipFlop), ff, "ff d={d} c={c}");
            assert_eq!(s.count(PrimitiveClass::MemoryLut), mlut, "mlut d={d} c={c}");
        }
    }

    #[test]
    fn serial_mac_logic_independent_of_coeff_width() {
        let cost_at = |c: usize| {
            let mut b = NetlistBuilder::new("t");
            let x = b.top_input_bus(8);
            let cb = b.top_input();
            let _ = bit_serial_mac(&mut b, "tap", &x, cb, c);
            b.finish().stats().count(PrimitiveClass::LogicLut)
        };
        assert_eq!(cost_at(3), cost_at(16), "serial MAC LUTs must not depend on c");
    }

    #[test]
    fn serial_mac_netlist_is_valid_with_feedback() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input_bus(5);
        let cb = b.top_input();
        let out = bit_serial_mac(&mut b, "tap", &x, cb, 7);
        assert_eq!(out.psum.len(), 6);
        b.finish().validate().unwrap();
    }
}
