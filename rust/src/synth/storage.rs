//! Storage generators: serial coefficient stores, window registers and line
//! buffers.
//!
//! The paper's blocks all use *serial* coefficient loading with local storage
//! ("chargement série et stockage local des coefficients du noyau 3×3") and
//! *parallel* data loading. The structures a synthesizer infers:
//!
//! * [`coeff_store_srl`] — a 1-bit-wide serial chain through SRL16s assembling
//!   the nine `c`-bit coefficients; a parallel-out tap register per coefficient
//!   word when the datapath needs word access (Conv2/3/4 feeding DSP B ports).
//! * [`window_regs`] — the 3×3 parallel data window (9 `d`-bit registers).
//! * [`line_buffer`] — RAM32M-based row buffer used when the block interfaces
//!   a streaming image (depth = image width), giving the MLUT ∝ d component.

use crate::netlist::{Bus, Net, NetlistBuilder};

/// Serial coefficient store for `n_coeff` coefficients of `c` bits each.
///
/// A single serial input threads through `n_coeff · ceil(c/16)` SRL16s; if
/// `parallel_out` is set, each coefficient word is additionally latched into a
/// `c`-bit FDRE register bank (needed when the consumer reads all words at
/// once, e.g. a DSP B-port mux), costing `n_coeff · c` flip-flops.
pub fn coeff_store_srl(
    b: &mut NetlistBuilder,
    label: &str,
    serial_in: Net,
    load_en: Net,
    n_coeff: usize,
    c: usize,
    parallel_out: bool,
) -> Vec<Bus> {
    assert!(n_coeff >= 1 && c >= 1, "coeff store needs sizes: {label}");
    b.push_scope(label);
    let mut chains: Vec<Bus> = Vec::with_capacity(n_coeff);
    let mut tail = serial_in;
    for _ in 0..n_coeff {
        // The word's bits live inside the SRL; expose the chain tap.
        let srls = c.div_ceil(16);
        for _ in 0..srls {
            tail = b.srl16("w_srl", tail, load_en);
        }
        let word: Bus = if parallel_out {
            // Word latch: c FFs capture the word when load completes.
            (0..c).map(|_| b.fdre("w_lat", tail)).collect()
        } else {
            vec![tail]
        };
        chains.push(word);
    }
    b.pop_scope();
    chains
}

/// 3×3 (or `n`-element) parallel data window: `n` registers of `d` bits.
pub fn window_regs(b: &mut NetlistBuilder, label: &str, data_in: &[Net], n: usize) -> Vec<Bus> {
    b.push_scope(label);
    let mut regs = Vec::with_capacity(n);
    let mut prev: Bus = data_in.to_vec();
    for k in 0..n {
        let q = b.fdre_bus(&format!("win{k}"), &prev);
        prev = q.clone();
        regs.push(q);
    }
    b.pop_scope();
    regs
}

/// Streaming row (line) buffer of `depth` entries × `d` bits. A fixed-length
/// delay line, so the synthesizer infers SRLC32E shift registers — the
/// cheapest mapping (no addressing logic): `d · ceil(depth/32)` SRL32s.
pub fn line_buffer(b: &mut NetlistBuilder, label: &str, data_in: &[Net], depth: usize) -> Bus {
    let d = data_in.len();
    assert!(d >= 1 && depth >= 1, "line buffer needs sizes: {label}");
    b.push_scope(label);
    let ce = b.lut("ce", &[data_in[0]]); // stream-valid gate
    let mut out: Bus = Vec::with_capacity(d);
    for &bit in data_in.iter() {
        let mut tail = bit;
        for _ in 0..depth.div_ceil(32) {
            tail = b.srl32("srl", tail, ce);
        }
        out.push(tail);
    }
    b.pop_scope();
    out
}

/// Coefficient-frame load FIFO: double-buffers a whole incoming coefficient
/// frame (`n_bits` = 9·c serial bits) in SRL32s so a new kernel can stream in
/// while the current one computes — the "chargement série ... pour optimiser
/// la mémoire" mechanism. Costs `ceil(n_bits/32)` SRL32s + one write gate.
/// This is the linear-in-`c` MLUT term of Table 3.
pub fn load_fifo(b: &mut NetlistBuilder, label: &str, serial_in: Net, load_en: Net, n_bits: usize) -> Net {
    assert!(n_bits >= 1, "load fifo needs bits: {label}");
    b.push_scope(label);
    let gated = b.lut("wr_gate", &[serial_in, load_en]);
    let mut tail = gated;
    for _ in 0..n_bits.div_ceil(32) {
        tail = b.srl32("fifo", tail, load_en);
    }
    b.pop_scope();
    tail
}

/// Analytical MLUT cost of the load FIFO.
pub fn load_fifo_mlut(n_bits: usize) -> u64 {
    n_bits.div_ceil(32) as u64
}

/// Analytical MLUT cost of a serial coefficient store (LUT-site units).
pub fn coeff_store_mlut(n_coeff: usize, c: usize) -> u64 {
    (n_coeff * c.div_ceil(16)) as u64
}

/// Analytical FF cost of the parallel-out latch bank.
pub fn coeff_store_ff(n_coeff: usize, c: usize) -> u64 {
    (n_coeff * c) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{NetlistBuilder, PrimitiveClass};

    #[test]
    fn coeff_store_srl_counts() {
        for (n, c) in [(9usize, 8usize), (9, 16), (9, 17), (4, 3)] {
            let mut b = NetlistBuilder::new("t");
            let si = b.top_input();
            let en = b.top_input();
            let words = coeff_store_srl(&mut b, "cs", si, en, n, c, false);
            assert_eq!(words.len(), n);
            let nl = b.finish();
            nl.validate().unwrap();
            assert_eq!(nl.stats().count(PrimitiveClass::MemoryLut), coeff_store_mlut(n, c));
            assert_eq!(nl.stats().count(PrimitiveClass::FlipFlop), 0);
        }
    }

    #[test]
    fn coeff_store_parallel_out_adds_ff() {
        let mut b = NetlistBuilder::new("t");
        let si = b.top_input();
        let en = b.top_input();
        let words = coeff_store_srl(&mut b, "cs", si, en, 9, 8, true);
        assert_eq!(words[0].len(), 8);
        let nl = b.finish();
        nl.validate().unwrap();
        assert_eq!(nl.stats().count(PrimitiveClass::FlipFlop), coeff_store_ff(9, 8));
    }

    #[test]
    fn window_regs_shift_structure() {
        let mut b = NetlistBuilder::new("t");
        let din = b.top_input_bus(8);
        let w = window_regs(&mut b, "win", &din, 3);
        assert_eq!(w.len(), 3);
        assert_eq!(w[2].len(), 8);
        let nl = b.finish();
        nl.validate().unwrap();
        assert_eq!(nl.stats().count(PrimitiveClass::FlipFlop), 24);
    }

    #[test]
    fn line_buffer_mlut_scales_with_width() {
        let cost = |d: usize| {
            let mut b = NetlistBuilder::new("t");
            let din = b.top_input_bus(d);
            let _ = line_buffer(&mut b, "lb", &din, 32);
            let n = b.finish();
            n.validate().unwrap();
            n.stats().count(PrimitiveClass::MemoryLut)
        };
        assert!(cost(16) > cost(8));
        assert!(cost(8) > cost(3));
        // One SRL32 per data bit for depth<=32.
        assert_eq!(cost(8), 8);
        // Depth 64: two SRL32 banks per bit.
        let mut b = NetlistBuilder::new("t");
        let din = b.top_input_bus(4);
        let _ = line_buffer(&mut b, "lb", &din, 64);
        assert_eq!(b.finish().stats().count(PrimitiveClass::MemoryLut), 8);
    }

    #[test]
    fn load_fifo_scales_linearly_with_bits() {
        let cost = |bits: usize| {
            let mut b = NetlistBuilder::new("t");
            let si = b.top_input();
            let en = b.top_input();
            let _ = load_fifo(&mut b, "lf", si, en, bits);
            let n = b.finish();
            n.validate().unwrap();
            n.stats().count(PrimitiveClass::MemoryLut)
        };
        assert_eq!(cost(27), 1); // 9 coeffs × 3 bits
        assert_eq!(cost(72), 3); // 9 × 8
        assert_eq!(cost(144), 5); // 9 × 16
        for bits in [27usize, 72, 144] {
            assert_eq!(cost(bits), load_fifo_mlut(bits));
        }
    }

    #[test]
    fn line_buffer_output_width_matches_input() {
        let mut b = NetlistBuilder::new("t");
        let din = b.top_input_bus(7);
        let out = line_buffer(&mut b, "lb", &din, 64);
        assert_eq!(out.len(), 7);
        b.finish().validate().unwrap();
    }
}
