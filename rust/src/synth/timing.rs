//! Static timing analysis over elaborated netlists.
//!
//! Levelizes the combinational graph and accumulates per-primitive delays
//! (typical UltraScale+ -2 speed-grade figures, DS925-class) to estimate the
//! critical path and achievable clock of each block — the numbers
//! `extend::latency::clock_mhz` quotes, now derived instead of asserted.
//! Registers (FDRE/SRL/DSP) are timing endpoints: paths are measured between
//! register boundaries, the way a synthesis timing report does.

use crate::netlist::{Netlist, Primitive};

/// Per-primitive propagation delays in picoseconds (typical -2 grade).
#[derive(Debug, Clone, Copy)]
pub struct DelayModel {
    /// LUT6 logic delay.
    pub lut_ps: f64,
    /// CARRY8 full-chain delay (8 bits).
    pub carry8_ps: f64,
    /// Wide-mux delay.
    pub muxf_ps: f64,
    /// Net (routing) delay added per hop.
    pub route_ps: f64,
    /// Register setup + clock-to-q margin charged once per path.
    pub reg_overhead_ps: f64,
    /// DSP48E2 fully-pipelined clock bound (ps period).
    pub dsp_period_ps: f64,
}

impl Default for DelayModel {
    fn default() -> Self {
        DelayModel {
            lut_ps: 150.0,
            carry8_ps: 120.0,
            muxf_ps: 75.0,
            route_ps: 180.0,
            reg_overhead_ps: 250.0,
            dsp_period_ps: 1540.0, // ~650 MHz f_max
        }
    }
}

/// Timing report for one netlist.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingReport {
    /// Longest register-to-register combinational path (ps).
    pub critical_path_ps: f64,
    /// Logic levels on the critical path.
    pub logic_levels: u32,
    /// Achievable clock (MHz), including the DSP pipeline bound.
    pub fmax_mhz: f64,
}

fn cell_delay(prim: &Primitive, m: &DelayModel) -> f64 {
    match prim {
        Primitive::Lut { .. } => m.lut_ps + m.route_ps,
        Primitive::Carry8 => m.carry8_ps, // chain routing is dedicated
        Primitive::MuxF => m.muxf_ps,
        // Registers and memories are endpoints, not path elements.
        _ => 0.0,
    }
}

fn is_endpoint(prim: &Primitive) -> bool {
    matches!(
        prim,
        Primitive::Fdre | Primitive::Srl16 | Primitive::Srl32 | Primitive::Ram32m | Primitive::Dsp48e2
    )
}

/// Analyze a netlist. Combinational loops (which only arise through register
/// feedback nets in our generators) are broken at endpoints; a genuinely
/// combinational cycle would indicate a generator bug and caps the iteration.
pub fn analyze(n: &Netlist, model: &DelayModel) -> TimingReport {
    // arrival[net] = (delay ps, levels) of the worst path from any endpoint
    // or top input to this net.
    let mut arrival: Vec<(f64, u32)> = vec![(f64::NEG_INFINITY, 0); n.net_count];
    for &t in &n.top_inputs {
        arrival[t.0] = (0.0, 0);
    }
    // Endpoint outputs launch new paths at t=0.
    for cell in &n.cells {
        if is_endpoint(&cell.prim) {
            for &o in &cell.outputs {
                arrival[o.0] = (0.0, 0);
            }
        }
    }
    // Relax combinational cells until fixpoint (graphs are shallow; bound the
    // passes to guard against accidental cycles).
    let mut worst = 0.0f64;
    let mut worst_levels = 0u32;
    for _pass in 0..64 {
        let mut changed = false;
        for cell in &n.cells {
            let d = cell_delay(&cell.prim, model);
            // Input arrival: max over inputs that have a defined arrival.
            let mut in_arr = f64::NEG_INFINITY;
            let mut in_lvl = 0u32;
            for &i in &cell.inputs {
                let (a, l) = arrival[i.0];
                if a > in_arr {
                    in_arr = a;
                    in_lvl = l;
                }
            }
            if in_arr == f64::NEG_INFINITY {
                continue;
            }
            if is_endpoint(&cell.prim) {
                // Path terminates here: record, don't propagate.
                let total = in_arr + model.reg_overhead_ps;
                if total > worst {
                    worst = total;
                    worst_levels = in_lvl;
                }
                continue;
            }
            let out_arr = in_arr + d;
            let out_lvl = in_lvl + 1;
            for &o in &cell.outputs {
                if out_arr > arrival[o.0].0 + 1e-9 {
                    arrival[o.0] = (out_arr, out_lvl);
                    changed = true;
                }
            }
            if out_arr + model.reg_overhead_ps > worst {
                worst = out_arr + model.reg_overhead_ps;
                worst_levels = out_lvl;
            }
        }
        if !changed {
            break;
        }
    }
    let has_dsp = n.cells.iter().any(|c| c.prim == Primitive::Dsp48e2);
    let period = worst.max(if has_dsp { model.dsp_period_ps } else { 0.0 }).max(1.0);
    TimingReport {
        critical_path_ps: worst,
        logic_levels: worst_levels,
        fmax_mhz: 1e6 / period,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockKind, ConvBlockConfig};
    use crate::netlist::NetlistBuilder;

    #[test]
    fn two_lut_chain_timing() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input();
        let y = b.lut("l1", &[x]);
        let z = b.lut("l2", &[y]);
        b.fdre("q", z);
        let rep = analyze(&b.finish(), &DelayModel::default());
        let m = DelayModel::default();
        let want = 2.0 * (m.lut_ps + m.route_ps) + m.reg_overhead_ps;
        assert!((rep.critical_path_ps - want).abs() < 1e-6, "{rep:?}");
        assert_eq!(rep.logic_levels, 2);
    }

    #[test]
    fn register_cuts_the_path() {
        let mut b = NetlistBuilder::new("t");
        let x = b.top_input();
        let y = b.lut("l1", &[x]);
        let q = b.fdre("q", y);
        let z = b.lut("l2", &[q]);
        b.fdre("q2", z);
        let rep = analyze(&b.finish(), &DelayModel::default());
        // Two single-LUT paths, not one 2-LUT path.
        assert_eq!(rep.logic_levels, 1, "{rep:?}");
    }

    #[test]
    fn conv_blocks_close_timing_in_plausible_bands() {
        let m = DelayModel::default();
        let fmax = |k: BlockKind| {
            let cfg = ConvBlockConfig::new(k, 8, 8).unwrap();
            analyze(&cfg.elaborate(), &m).fmax_mhz
        };
        let f1 = fmax(BlockKind::Conv1);
        let f2 = fmax(BlockKind::Conv2);
        // The fabric array multiplier is the slowest datapath.
        assert!(f1 < f2, "Conv1 {f1} vs Conv2 {f2}");
        for k in BlockKind::ALL {
            let f = fmax(k);
            assert!((80.0..=800.0).contains(&f), "{k}: {f} MHz");
        }
    }

    #[test]
    fn wider_multiplier_is_slower() {
        let m = DelayModel::default();
        let f = |d: u32, c: u32| {
            let cfg = ConvBlockConfig::new(BlockKind::Conv1, d, c).unwrap();
            analyze(&cfg.elaborate(), &m).fmax_mhz
        };
        assert!(f(16, 16) < f(4, 4));
    }

    #[test]
    fn feedback_loops_terminate() {
        // Accumulator feedback (FDRE into its own adder) must not hang.
        let cfg = ConvBlockConfig::new(BlockKind::Conv1, 8, 8).unwrap();
        let rep = analyze(&cfg.elaborate(), &DelayModel::default());
        assert!(rep.critical_path_ps.is_finite());
        assert!(rep.logic_levels > 0);
    }
}
