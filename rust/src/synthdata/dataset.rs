//! Measurement records and the dataset container.

use crate::blocks::BlockKind;
use crate::synth::{Resource, ResourceVector};
use crate::util::csv;
use crate::util::error::{Error, Result};

/// One synthesis measurement: a configuration and its utilization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SynthRecord {
    /// Block microarchitecture.
    pub block: BlockKind,
    /// Data width (bits).
    pub data_bits: u32,
    /// Coefficient width (bits).
    pub coeff_bits: u32,
    /// Measured utilization.
    pub res: ResourceVector,
}

/// A collection of synthesis measurements.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// All records, in sweep order.
    pub records: Vec<SynthRecord>,
}

impl Dataset {
    /// Records for one block.
    pub fn for_block(&self, block: BlockKind) -> Vec<&SynthRecord> {
        self.records.iter().filter(|r| r.block == block).collect()
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Extract `(d, c, y)` regression samples for one block and resource.
    pub fn samples(&self, block: BlockKind, resource: Resource) -> Vec<(f64, f64, f64)> {
        self.for_block(block)
            .iter()
            .map(|r| (r.data_bits as f64, r.coeff_bits as f64, r.res.get(resource) as f64))
            .collect()
    }

    /// Column vectors (data widths, coeff widths, per-resource counts) for the
    /// correlation analysis.
    pub fn columns(&self, block: BlockKind) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
        let recs = self.for_block(block);
        let d: Vec<f64> = recs.iter().map(|r| r.data_bits as f64).collect();
        let c: Vec<f64> = recs.iter().map(|r| r.coeff_bits as f64).collect();
        let ys: Vec<Vec<f64>> = Resource::ALL
            .iter()
            .map(|&res| recs.iter().map(|r| r.res.get(res) as f64).collect())
            .collect();
        (d, c, ys)
    }

    /// Look up one record.
    pub fn get(&self, block: BlockKind, d: u32, c: u32) -> Option<&SynthRecord> {
        self.records
            .iter()
            .find(|r| r.block == block && r.data_bits == d && r.coeff_bits == c)
    }

    /// Serialize to CSV text.
    pub fn to_csv(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .records
            .iter()
            .map(|r| {
                vec![
                    r.block.name().to_string(),
                    r.data_bits.to_string(),
                    r.coeff_bits.to_string(),
                    r.res.llut.to_string(),
                    r.res.mlut.to_string(),
                    r.res.ff.to_string(),
                    r.res.cchain.to_string(),
                    r.res.dsp.to_string(),
                ]
            })
            .collect();
        csv::write_csv(
            &["block", "data_bits", "coeff_bits", "llut", "mlut", "ff", "cchain", "dsp"],
            &rows,
        )
    }

    /// Parse from CSV text (inverse of [`Self::to_csv`]).
    pub fn from_csv(text: &str) -> Result<Dataset> {
        let (header, rows) = csv::read_csv(text)?;
        let expect = ["block", "data_bits", "coeff_bits", "llut", "mlut", "ff", "cchain", "dsp"];
        if header != expect {
            return Err(Error::Parse(format!("unexpected dataset header: {header:?}")));
        }
        let mut records = Vec::with_capacity(rows.len());
        for row in rows {
            let block = BlockKind::parse(&row[0])
                .ok_or_else(|| Error::Parse(format!("unknown block `{}`", row[0])))?;
            records.push(SynthRecord {
                block,
                data_bits: row[1].parse::<u32>()?,
                coeff_bits: row[2].parse::<u32>()?,
                res: ResourceVector::new(
                    row[3].parse()?,
                    row[4].parse()?,
                    row[5].parse()?,
                    row[6].parse()?,
                    row[7].parse()?,
                ),
            });
        }
        Ok(Dataset { records })
    }

    /// Save to a file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Dataset> {
        Dataset::from_csv(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            records: vec![
                SynthRecord {
                    block: BlockKind::Conv1,
                    data_bits: 3,
                    coeff_bits: 4,
                    res: ResourceVector::new(10, 2, 5, 1, 0),
                },
                SynthRecord {
                    block: BlockKind::Conv2,
                    data_bits: 8,
                    coeff_bits: 8,
                    res: ResourceVector::new(25, 40, 20, 0, 1),
                },
                SynthRecord {
                    block: BlockKind::Conv1,
                    data_bits: 4,
                    coeff_bits: 4,
                    res: ResourceVector::new(12, 2, 6, 1, 0),
                },
            ],
        }
    }

    #[test]
    fn filtering_and_lookup() {
        let ds = tiny();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.for_block(BlockKind::Conv1).len(), 2);
        assert_eq!(ds.get(BlockKind::Conv2, 8, 8).unwrap().res.dsp, 1);
        assert!(ds.get(BlockKind::Conv4, 8, 8).is_none());
    }

    #[test]
    fn samples_extraction() {
        let ds = tiny();
        let s = ds.samples(BlockKind::Conv1, Resource::Llut);
        assert_eq!(s, vec![(3.0, 4.0, 10.0), (4.0, 4.0, 12.0)]);
    }

    #[test]
    fn columns_shapes() {
        let ds = tiny();
        let (d, c, ys) = ds.columns(BlockKind::Conv1);
        assert_eq!(d.len(), 2);
        assert_eq!(c, vec![4.0, 4.0]);
        assert_eq!(ys.len(), 5);
        assert_eq!(ys[0], vec![10.0, 12.0]); // LLUT column
    }

    #[test]
    fn csv_roundtrip() {
        let ds = tiny();
        let text = ds.to_csv();
        let back = Dataset::from_csv(&text).unwrap();
        assert_eq!(back.records, ds.records);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(Dataset::from_csv("a,b\n1,2\n").is_err());
        assert!(Dataset::from_csv(
            "block,data_bits,coeff_bits,llut,mlut,ff,cchain,dsp\nConvX,1,2,3,4,5,6,7\n"
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let ds = tiny();
        let path = std::env::temp_dir().join("convkit_test_dataset.csv");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.records, ds.records);
        let _ = std::fs::remove_file(&path);
    }
}
