//! The synthesis data-collection campaign (paper §3.2).
//!
//! 196 configurations per block — data and coefficient widths swept 3..=16 —
//! synthesized through the [`crate::synth`] simulator, with the measurements
//! stored as a [`Dataset`] (CSV-persistable so the fitting/reporting stages
//! and external plotting tools can run without re-synthesis).

pub mod dataset;
pub mod sweep;

pub use dataset::{Dataset, SynthRecord};
pub use sweep::{run_sweep, sweep_configs, SweepOptions};
