//! The sweep driver: enumerate configurations, synthesize each, collect the
//! dataset. Mirrors the outer loops of the paper's Algorithm 1.

use super::dataset::{Dataset, SynthRecord};
use crate::blocks::{synthesize, BlockKind, ConvBlockConfig, SWEEP_MAX_BITS, SWEEP_MIN_BITS};
use crate::synth::MapOptions;
use crate::util::error::Result;

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Blocks to sweep (default: every registered block).
    pub blocks: Vec<BlockKind>,
    /// Width range (inclusive); defaults to the paper's 3..=16.
    pub min_bits: u32,
    /// Upper bound (inclusive).
    pub max_bits: u32,
    /// Mapper options (jitter on by default, as Vivado measurements would be).
    pub map: MapOptions,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            blocks: BlockKind::ALL.to_vec(),
            min_bits: SWEEP_MIN_BITS,
            max_bits: SWEEP_MAX_BITS,
            map: MapOptions::default(),
        }
    }
}

/// Enumerate the sweep's configurations in the paper's loop order
/// (block → data width → coefficient width).
pub fn sweep_configs(opts: &SweepOptions) -> Vec<ConvBlockConfig> {
    let mut cfgs = Vec::new();
    for &block in &opts.blocks {
        for d in opts.min_bits..=opts.max_bits {
            for c in opts.min_bits..=opts.max_bits {
                cfgs.push(
                    ConvBlockConfig::new(block, d, c)
                        .expect("sweep range is inside the valid range"),
                );
            }
        }
    }
    cfgs
}

/// Run the sweep: one synthesis per configuration.
///
/// With the default options this is the paper's full campaign:
/// 4 blocks × 14 × 14 = 784 synthesis runs (196 per block).
pub fn run_sweep(opts: &SweepOptions) -> Result<Dataset> {
    let cfgs = sweep_configs(opts);
    let mut records = Vec::with_capacity(cfgs.len());
    for cfg in &cfgs {
        let res = synthesize(cfg, &opts.map);
        records.push(SynthRecord {
            block: cfg.kind,
            data_bits: cfg.data_bits,
            coeff_bits: cfg.coeff_bits,
            res,
        });
    }
    Ok(Dataset { records })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::Resource;

    fn small_opts() -> SweepOptions {
        SweepOptions { min_bits: 3, max_bits: 6, ..Default::default() }
    }

    #[test]
    fn config_count_matches_paper() {
        // 196 configurations per registered block; the paper's four-block
        // subset reproduces its 784-run campaign exactly.
        let opts = SweepOptions::default();
        assert_eq!(sweep_configs(&opts).len(), BlockKind::ALL.len() * 196);
        let paper = SweepOptions { blocks: BlockKind::PAPER.to_vec(), ..Default::default() };
        assert_eq!(sweep_configs(&paper).len(), 4 * 14 * 14);
        let one = SweepOptions { blocks: vec![BlockKind::Conv2], ..Default::default() };
        assert_eq!(sweep_configs(&one).len(), 196);
    }

    #[test]
    fn small_sweep_produces_full_grid() {
        let ds = run_sweep(&small_opts()).unwrap();
        assert_eq!(ds.len(), BlockKind::ALL.len() * 4 * 4);
        for block in BlockKind::ALL {
            assert_eq!(ds.for_block(block).len(), 16);
        }
        // DSP counts are structural.
        for r in &ds.records {
            assert_eq!(r.res.dsp, r.block.dsp_count());
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run_sweep(&small_opts()).unwrap();
        let b = run_sweep(&small_opts()).unwrap();
        assert_eq!(a.records, b.records);
    }

    #[test]
    fn jitterless_sweep_is_monotone_for_conv1_llut() {
        let opts = SweepOptions {
            blocks: vec![BlockKind::Conv1],
            min_bits: 3,
            max_bits: 8,
            map: MapOptions::exact(),
        };
        let ds = run_sweep(&opts).unwrap();
        // Fixed c: LLUT non-decreasing in d.
        for c in 3..=8u32 {
            let mut prev = 0u64;
            for d in 3..=8u32 {
                let v = ds.get(BlockKind::Conv1, d, c).unwrap().res.llut;
                assert!(v >= prev, "c={c} d={d}: {v} < {prev}");
                prev = v;
            }
        }
        let s = ds.samples(BlockKind::Conv1, Resource::Llut);
        assert_eq!(s.len(), 36);
    }
}
