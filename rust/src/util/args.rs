//! Zero-dependency CLI argument parser (clap is unavailable offline).
//!
//! Supports the subset the `convkit` binary needs: one subcommand followed by
//! `--flag`, `--key value` / `--key=value` options and positional arguments,
//! with typed accessors and error messages that point at the offending token.

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand + options + positionals.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    /// First non-flag token (e.g. `sweep`, `fit`, `allocate`).
    pub command: Option<String>,
    /// `--key value` and `--key=value` pairs; bare `--flag` maps to "true".
    options: BTreeMap<String, String>,
    /// Remaining positional tokens after the subcommand.
    pub positional: Vec<String>,
}

/// Option keys that take no value (everything else consumes the next token).
const BOOLEAN_FLAGS: &[&str] = &[
    "help", "french", "verbose", "quiet", "csv", "no-jitter", "release-check",
    "ascii", "exhaustive", "per-block", "golden-only", "skip-runtime",
    "latency-slo", "no-latency-slo",
];

impl ParsedArgs {
    /// Parse tokens (without argv[0]).
    pub fn parse<I, S>(tokens: I) -> Result<ParsedArgs>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = ParsedArgs::default();
        let mut it = tokens.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` ends option parsing.
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if BOOLEAN_FLAGS.contains(&stripped) {
                    out.options.insert(stripped.to_string(), "true".to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.options.insert(stripped.to_string(), v);
                        }
                        Some(v) => {
                            return Err(Error::Usage(format!(
                                "option --{stripped} expects a value, got `{v}`"
                            )))
                        }
                        None => {
                            return Err(Error::Usage(format!(
                                "option --{stripped} expects a value"
                            )))
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Raw option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Boolean flag presence.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed accessor with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    /// Typed accessor with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key} expects a number, got `{v}`"))),
        }
    }

    /// String accessor with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Comma-separated list accessor.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> ParsedArgs {
        ParsedArgs::parse(toks.iter().copied()).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["fit", "conv1", "conv2"]);
        assert_eq!(a.command.as_deref(), Some("fit"));
        assert_eq!(a.positional, vec!["conv1", "conv2"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse(&["sweep", "--seed", "7", "--out=data.csv"]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.get("out"), Some("data.csv"));
    }

    #[test]
    fn boolean_flags_do_not_eat_tokens() {
        let a = parse(&["tables", "--french", "3"]);
        assert!(a.flag("french"));
        assert_eq!(a.positional, vec!["3"]);
    }

    #[test]
    fn missing_value_is_usage_error() {
        assert!(ParsedArgs::parse(["fit", "--degree"]).is_err());
        assert!(ParsedArgs::parse(["fit", "--degree", "--other", "1"]).is_err());
    }

    #[test]
    fn double_dash_stops_option_parsing() {
        let a = parse(&["run", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn typed_accessors_validate() {
        let a = parse(&["x", "--n", "abc", "--f", "0.5"]);
        assert!(a.get_u64("n", 0).is_err());
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_str("missing", "dflt"), "dflt");
    }

    #[test]
    fn list_accessor_splits_and_trims() {
        let a = parse(&["x", "--blocks", "conv1, conv2 ,,conv4"]);
        assert_eq!(a.get_list("blocks"), vec!["conv1", "conv2", "conv4"]);
        assert!(a.get_list("nope").is_empty());
    }
}
