//! Miniature benchmark harness (`criterion` is unavailable offline).
//!
//! Used by the `[[bench]]` targets (all `harness = false`): warms up, runs
//! timed batches until a wall-clock budget or iteration cap is reached, and
//! reports mean / p50 / p95 per iteration plus derived throughput. Output is
//! deliberately criterion-like one-liners so `cargo bench | tee` logs read
//! familiarly.

use crate::util::format::fmt_duration;
use std::time::{Duration, Instant};

/// One benchmark's collected statistics (per-iteration nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    /// Iterations per second implied by the mean.
    pub fn throughput(&self) -> f64 {
        1e9 / self.mean_ns.max(1e-9)
    }

    /// criterion-style report line.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]   ({:.1} elem/s, {} iters)",
            self.name,
            fmt_duration(self.min_ns),
            fmt_duration(self.p50_ns),
            fmt_duration(self.p95_ns),
            self.throughput(),
            self.iters
        )
    }
}

/// Harness accumulating results for a bench binary.
#[derive(Debug, Default)]
pub struct Bench {
    /// Target measurement time per benchmark.
    pub budget: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: u64,
    results: Vec<BenchStats>,
}

impl Bench {
    /// Standard settings: 2 s budget, 1e6 iteration cap (CI-friendly on 1 CPU).
    pub fn new() -> Self {
        Bench { budget: Duration::from_secs(2), max_iters: 1_000_000, results: Vec::new() }
    }

    /// Quick settings for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bench { budget: Duration::from_millis(500), max_iters: 10_000, results: Vec::new() }
    }

    /// Time `f`, which performs ONE logical iteration and returns a value that
    /// is passed through `std::hint::black_box` to defeat DCE.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warm-up: a few untimed iterations (also primes caches/allocator).
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.budget / 10 && warm_iters < self.max_iters / 10 + 1 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Measurement: batch so that clock overhead is amortized for fast fns.
        let per_call_est = (warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64).max(0.5);
        let batch = ((100_000.0 / per_call_est).ceil() as u64).clamp(1, 10_000);
        let mut samples: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            min_ns: samples[0],
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// The results array, serialized (hand-rolled — no serde offline).
    fn results_json(&self, indent: &str) -> String {
        let mut out = String::new();
        for (i, s) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "{indent}{{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}, \
                 \"throughput_per_s\": {:.1}}}{}\n",
                s.name,
                s.iters,
                s.mean_ns,
                s.p50_ns,
                s.p95_ns,
                s.min_ns,
                s.throughput(),
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        out
    }

    /// Serialize all collected results as a single-bench JSON baseline.
    /// Shape: `{"bench": NAME, "results": [{"name": ..., "iters": N,
    /// "mean_ns": ..., "p50_ns": ..., "p95_ns": ..., "min_ns": ...,
    /// "throughput_per_s": ...}, ...]}`.
    pub fn to_json(&self, bench_name: &str) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n  \"results\": [\n", bench_name));
        out.push_str(&self.results_json("    "));
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the single-bench JSON baseline (overwrites `path`).
    pub fn write_json(&self, bench_name: &str, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(bench_name))
    }

    /// This bench's section body for the multi-section baseline format.
    fn section_json(&self) -> String {
        format!("{{\"results\": [\n{}    ]}}", self.results_json("      "))
    }

    /// Read-modify-write `path` as a *multi-section* baseline so several
    /// bench binaries can share one perf-trajectory file (CI archives a
    /// single `BENCH_runtime.json`). Shape:
    /// `{"benches": {NAME: {"results": [...]}, ...}}` — this bench's section
    /// replaces any previous section of the same name, other sections are
    /// preserved. A missing, old-format, or unparsable file starts fresh.
    pub fn write_json_sections(
        &self,
        bench_name: &str,
        path: &std::path::Path,
    ) -> std::io::Result<()> {
        let mut sections = match std::fs::read_to_string(path) {
            Ok(text) => parse_sections(&text),
            Err(_) => Vec::new(),
        };
        sections.retain(|(name, _)| name != bench_name);
        sections.push((bench_name.to_string(), self.section_json()));
        let mut out = String::from("{\n  \"benches\": {\n");
        for (i, (name, body)) in sections.iter().enumerate() {
            out.push_str(&format!(
                "    \"{}\": {}{}\n",
                name,
                body,
                if i + 1 == sections.len() { "" } else { "," }
            ));
        }
        out.push_str("  }\n}\n");
        std::fs::write(path, out)
    }

    /// Find a result by name (for speedup-ratio reporting inside a bench).
    pub fn stats(&self, name: &str) -> Option<&BenchStats> {
        self.results.iter().find(|s| s.name == name)
    }
}

/// Extract `(name, body)` pairs from a multi-section baseline written by
/// [`Bench::write_json_sections`]. Minimal by design: section bodies are
/// located by balanced-brace scanning, which is sound because the writer
/// never emits `{`/`}` inside string values (bench names are identifiers).
/// Returns an empty list for old-format or foreign files — callers then
/// start a fresh baseline.
fn parse_sections(text: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let Some(benches_at) = text.find("\"benches\"") else {
        return out;
    };
    let after_key = &text[benches_at + "\"benches\"".len()..];
    let Some(open) = after_key.find('{') else {
        return out;
    };
    let mut rest = &after_key[open + 1..];
    loop {
        // `"<name>": { ... }` — name, then the balanced-brace body.
        let Some(q0) = rest.find('"') else { break };
        let after_quote = &rest[q0 + 1..];
        let Some(q1) = after_quote.find('"') else { break };
        let name = &after_quote[..q1];
        let after_name = &after_quote[q1 + 1..];
        let Some(b0) = after_name.find('{') else { break };
        let mut depth = 0usize;
        let mut body_end = None;
        for (i, c) in after_name[b0..].char_indices() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        body_end = Some(b0 + i);
                        break;
                    }
                }
                _ => {}
            }
        }
        let Some(end) = body_end else { break };
        out.push((name.to_string(), after_name[b0..=end].to_string()));
        rest = &after_name[end + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench { budget: Duration::from_millis(30), max_iters: 100_000, results: vec![] };
        let s = b.run("noop-ish", || 1 + 1).clone();
        assert!(s.mean_ns > 0.0);
        assert!(s.iters > 0);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.min_ns <= s.p50_ns);
    }

    #[test]
    fn report_contains_name_and_units() {
        let mut b = Bench { budget: Duration::from_millis(10), max_iters: 1_000, results: vec![] };
        b.run("my_bench", || 0u8);
        let line = b.stats("my_bench").unwrap().report();
        assert!(line.contains("my_bench"));
        assert!(line.contains("time:"));
    }

    #[test]
    fn json_baseline_well_formed() {
        let mut b = Bench { budget: Duration::from_millis(10), max_iters: 1_000, results: vec![] };
        b.run("alpha", || 1u8);
        b.run("beta", || 2u8);
        let j = b.to_json("runtime_conv");
        assert!(j.contains("\"bench\": \"runtime_conv\""));
        assert!(j.contains("\"name\": \"alpha\""));
        assert!(j.contains("\"throughput_per_s\""));
        // Exactly one comma-separated pair of result objects.
        assert_eq!(j.matches("\"name\":").count(), 2);
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn sectioned_baseline_merges_across_benches() {
        let dir = std::env::temp_dir().join("convkit_bench_sections_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let _ = std::fs::remove_file(&path);

        let mut conv = Bench { budget: Duration::from_millis(5), max_iters: 500, results: vec![] };
        conv.run("conv_a", || 1u8);
        conv.write_json_sections("runtime_conv", &path).unwrap();

        let mut serve = Bench { budget: Duration::from_millis(5), max_iters: 500, results: vec![] };
        serve.run("fleet_a", || 2u8);
        serve.write_json_sections("runtime_serve", &path).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"runtime_conv\""), "{text}");
        assert!(text.contains("\"runtime_serve\""), "{text}");
        assert!(text.contains("\"conv_a\""), "{text}");
        assert!(text.contains("\"fleet_a\""), "{text}");

        // Re-writing one section replaces it without duplicating the other.
        let mut serve2 = Bench { budget: Duration::from_millis(5), max_iters: 500, results: vec![] };
        serve2.run("fleet_b", || 3u8);
        serve2.write_json_sections("runtime_serve", &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("\"runtime_serve\"").count(), 1, "{text}");
        assert!(text.contains("\"conv_a\""), "other section preserved: {text}");
        assert!(!text.contains("\"fleet_a\""), "stale section dropped: {text}");
        assert!(text.contains("\"fleet_b\""), "{text}");

        let sections = parse_sections(&text);
        assert_eq!(sections.len(), 2);
        assert_eq!(sections[0].0, "runtime_conv");
        assert_eq!(sections[1].0, "runtime_serve");
        assert!(sections[1].1.contains("\"fleet_b\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn old_format_baseline_starts_fresh() {
        assert!(parse_sections("{\"bench\": \"runtime_conv\", \"results\": []}").is_empty());
        assert!(parse_sections("").is_empty());
        assert!(parse_sections("{\"benches\": {}}").is_empty());
    }

    #[test]
    fn ordering_of_percentiles_holds_for_slow_fn() {
        let mut b = Bench { budget: Duration::from_millis(20), max_iters: 2_000, results: vec![] };
        let s = b
            .run("spin", || {
                let mut acc = 0u64;
                for i in 0..100 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .clone();
        assert!(s.p95_ns >= s.p50_ns);
        assert!(s.throughput() > 0.0);
    }
}
