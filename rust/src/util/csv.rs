//! Minimal CSV reader/writer for dataset persistence.
//!
//! The synthesis campaign (`synthdata`) persists its 4 × 196 measurement matrix
//! as CSV so the fitting and reporting stages — and external plotting tools —
//! can consume it without the simulator. Quoting is supported on read, never
//! needed on write (all our fields are identifiers or numbers).

use crate::util::error::{Error, Result};

/// Serialize rows (first row = header) to CSV text.
pub fn write_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Parse CSV text into (header, rows). Handles double-quoted fields with
/// embedded commas/quotes; does not handle embedded newlines (not produced by
/// any of our writers).
pub fn read_csv(text: &str) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = match lines.next() {
        Some(h) => parse_line(h)?,
        None => return Err(Error::Parse("empty csv".into())),
    };
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        let row = parse_line(line)?;
        if row.len() != header.len() {
            return Err(Error::Parse(format!(
                "row {} has {} fields, header has {}",
                i + 1,
                row.len(),
                header.len()
            )));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

fn parse_line(line: &str) -> Result<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(ch) = chars.next() {
        if in_quotes {
            match ch {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cur.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => cur.push(c),
            }
        } else {
            match ch {
                ',' => fields.push(std::mem::take(&mut cur)),
                '"' if cur.is_empty() => in_quotes = true,
                c => cur.push(c),
            }
        }
    }
    if in_quotes {
        return Err(Error::Parse(format!("unterminated quote in line: {line}")));
    }
    fields.push(cur);
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let header = ["a", "b", "c"];
        let rows = vec![
            vec!["1".to_string(), "2".to_string(), "3".to_string()],
            vec!["x".to_string(), "y".to_string(), "z".to_string()],
        ];
        let text = write_csv(&header, &rows);
        let (h, r) = read_csv(&text).unwrap();
        assert_eq!(h, vec!["a", "b", "c"]);
        assert_eq!(r, rows);
    }

    #[test]
    fn quoted_fields_with_commas_and_quotes() {
        let (h, r) = read_csv("name,desc\nconv1,\"a, \"\"b\"\"\"\n").unwrap();
        assert_eq!(h, vec!["name", "desc"]);
        assert_eq!(r[0][1], "a, \"b\"");
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(read_csv("a,b\n1\n").is_err());
    }

    #[test]
    fn rejects_empty_input_and_unterminated_quote() {
        assert!(read_csv("").is_err());
        assert!(read_csv("a,b\n\"oops,1\n").is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let (_, r) = read_csv("a,b\n\n1,2\n\n3,4\n").unwrap();
        assert_eq!(r.len(), 2);
    }
}
