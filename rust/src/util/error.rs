//! Crate-wide error type.
//!
//! A hand-rolled enum (no `thiserror` offline) with `From` conversions for the
//! handful of foreign error types that cross module boundaries.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways convkit operations can fail.
#[derive(Debug)]
pub enum Error {
    /// A block was configured outside its supported parameter range
    /// (e.g. `Conv3` with data width > 8, or any width outside 1..=32).
    InvalidConfig(String),
    /// Numerical routine failed (singular system, empty dataset, ...).
    Numerical(String),
    /// Model fitting could not reach the paper's acceptance threshold.
    ModelRejected(String),
    /// Allocation is infeasible under the requested utilization cap.
    Infeasible(String),
    /// CLI usage error.
    Usage(String),
    /// Dataset / CSV parsing problem.
    Parse(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Admission rejected: a serving shard's bounded request queue is at
    /// capacity (backpressure — retry later or route elsewhere).
    Overloaded(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidConfig(m) => write!(f, "invalid configuration: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::ModelRejected(m) => write!(f, "model rejected: {m}"),
            Error::Infeasible(m) => write!(f, "infeasible allocation: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::Parse(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::Parse(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_prefixed() {
        assert!(Error::InvalidConfig("x".into()).to_string().starts_with("invalid configuration"));
        assert!(Error::Numerical("x".into()).to_string().starts_with("numerical"));
        assert!(Error::Infeasible("x".into()).to_string().starts_with("infeasible"));
        assert!(Error::Usage("x".into()).to_string().starts_with("usage"));
        assert!(Error::Overloaded("x".into()).to_string().starts_with("overloaded"));
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }

    #[test]
    fn parse_errors_convert() {
        let e: Error = "abc".parse::<i64>().unwrap_err().into();
        assert!(matches!(e, Error::Parse(_)));
        let e: Error = "abc".parse::<f64>().unwrap_err().into();
        assert!(matches!(e, Error::Parse(_)));
    }
}
