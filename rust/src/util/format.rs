//! Plain-text table rendering for CLI reports and bench output.
//!
//! The paper's evaluation is entirely tables and fitted-surface figures; this
//! module renders both in a terminal (tables as aligned ASCII grids, surfaces as
//! a coarse height map) and keeps the machine-readable CSV path separate
//! (`util::csv`).

use std::fmt::Write as _;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// An ASCII table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers; all columns right-aligned except
    /// the first.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        let header: Vec<String> = header.into_iter().map(Into::into).collect();
        let mut align = vec![Align::Right; header.len()];
        if !align.is_empty() {
            align[0] = Align::Left;
        }
        Table { title: None, header, align, rows: Vec::new() }
    }

    /// Attach a caption rendered above the grid.
    pub fn with_title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Override one column's alignment.
    pub fn set_align(&mut self, col: usize, align: Align) {
        if col < self.align.len() {
            self.align[col] = align;
        }
    }

    /// Append a row; short rows are padded with empty cells, long rows truncated.
    pub fn push_row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let mut row: Vec<String> = cells.into_iter().map(Into::into).collect();
        row.resize(self.header.len(), String::new());
        self.rows.push(row);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "{t}");
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], align: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i].saturating_sub(cell.chars().count());
                match align[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(cell);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(cell);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.header, &vec![Align::Left; ncol]));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &self.align));
        }
        let _ = writeln!(out, "{sep}");
        out
    }
}

/// Format a float with a fixed number of decimals, using the paper's French
/// convention (comma decimal separator) when `french` is set. Used so the
/// regenerated tables can be compared side by side with the paper's.
pub fn fmt_num(v: f64, decimals: usize, french: bool) -> String {
    let s = format!("{v:.decimals$}");
    if french {
        s.replace('.', ",")
    } else {
        s
    }
}

/// Render a coarse ASCII "surface" (the paper's Figures 1-3 are 3-D fitted
/// surfaces; in a terminal we show the height map over the (d, c) grid using a
/// 10-level ramp).
pub fn ascii_surface(
    title: &str,
    xs: &[i64],
    ys: &[i64],
    z: impl Fn(i64, i64) -> f64,
) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        for &y in ys {
            let v = z(x, y);
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let span = (hi - lo).max(1e-12);
    let mut out = String::new();
    let _ = writeln!(out, "{title}  (z in [{lo:.1}, {hi:.1}], rows=coeff bits, cols=data bits)");
    let _ = write!(out, "      ");
    for &x in xs {
        let _ = write!(out, "{x:>3}");
    }
    let _ = writeln!(out);
    for &y in ys.iter().rev() {
        let _ = write!(out, "c={y:>3} ");
        for &x in xs {
            let v = z(x, y);
            let idx = (((v - lo) / span) * (RAMP.len() - 1) as f64).round() as usize;
            let ch = RAMP[idx.min(RAMP.len() - 1)] as char;
            let _ = write!(out, "  {ch}");
        }
        let _ = writeln!(out);
    }
    out
}

/// Human formatting for durations in bench output.
pub fn fmt_duration(nanos: f64) -> String {
    if nanos < 1_000.0 {
        format!("{nanos:.1} ns")
    } else if nanos < 1_000_000.0 {
        format!("{:.2} µs", nanos / 1_000.0)
    } else if nanos < 1_000_000_000.0 {
        format!("{:.2} ms", nanos / 1_000_000.0)
    } else {
        format!("{:.3} s", nanos / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned_grid() {
        let mut t = Table::new(vec!["name", "value"]).with_title("demo");
        t.push_row(vec!["alpha", "1"]);
        t.push_row(vec!["b", "12345"]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("| alpha |"));
        // Right alignment on the numeric column.
        assert!(s.contains("|     1 |"));
        assert!(s.contains("| 12345 |"));
    }

    #[test]
    fn short_rows_padded_long_rows_truncated() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push_row(vec!["only"]);
        t.push_row(vec!["x", "y"]);
        let s = t.render();
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
        assert!(s.contains("| only |"));
    }

    #[test]
    fn fmt_num_french_convention() {
        assert_eq!(fmt_num(20.886, 3, true), "20,886");
        assert_eq!(fmt_num(20.886, 3, false), "20.886");
        assert_eq!(fmt_num(1.0, 2, true), "1,00");
    }

    #[test]
    fn surface_has_expected_dimensions() {
        let xs: Vec<i64> = (3..=6).collect();
        let ys: Vec<i64> = (3..=5).collect();
        let s = ascii_surface("t", &xs, &ys, |x, y| (x * y) as f64);
        // header + column-index line + 3 data rows
        assert_eq!(s.lines().count(), 2 + ys.len());
        assert!(s.contains("c=  5"));
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(12.0), "12.0 ns");
        assert_eq!(fmt_duration(12_000.0), "12.00 µs");
        assert_eq!(fmt_duration(12_000_000.0), "12.00 ms");
        assert_eq!(fmt_duration(2_500_000_000.0), "2.500 s");
    }
}
