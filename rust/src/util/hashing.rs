//! Stable (platform- and run-independent) hashing.
//!
//! `std::collections::hash_map::DefaultHasher` is explicitly not stable across
//! releases, and the synthesis-jitter emulation (see `synth::jitter`) must produce
//! the *same* pseudo-Vivado noise for a given configuration forever — the fitted
//! models in EXPERIMENTS.md depend on it. FNV-1a over a byte encoding is tiny,
//! stable, and good enough for seeding.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Hash a sequence of u64 words (order-sensitive).
pub fn fnv1a_words(words: &[u64]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &w in words {
        for i in 0..8 {
            h ^= (w >> (8 * i)) & 0xFF;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Hash a string label together with numeric parameters; the workhorse for
/// per-configuration deterministic seeds.
pub fn stable_seed(label: &str, params: &[u64]) -> u64 {
    let mut h = fnv1a(label.as_bytes());
    h ^= fnv1a_words(params).rotate_left(32);
    // Final avalanche so nearby parameter tuples decohere.
    let mut z = h;
    z = (z ^ (z >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z = (z ^ (z >> 33)).wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    z ^ (z >> 33)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Reference values for FNV-1a 64-bit.
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn words_order_sensitive() {
        assert_ne!(fnv1a_words(&[1, 2]), fnv1a_words(&[2, 1]));
    }

    #[test]
    fn stable_seed_distinguishes_labels_and_params() {
        let a = stable_seed("conv1", &[8, 8]);
        let b = stable_seed("conv2", &[8, 8]);
        let c = stable_seed("conv1", &[8, 9]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn stable_seed_is_actually_stable() {
        // Frozen regression values: if these change, every dataset the models
        // were calibrated on changes too. Do not update casually.
        assert_eq!(stable_seed("conv1", &[8, 8]), stable_seed("conv1", &[8, 8]));
        let frozen = stable_seed("llut", &[1, 3, 16]);
        assert_eq!(frozen, stable_seed("llut", &[1, 3, 16]));
    }
}
