//! Cross-cutting utilities: deterministic PRNG, stable hashing, error type,
//! table/CSV formatting, a zero-dependency CLI argument parser and a miniature
//! property-testing harness.
//!
//! The build environment is fully offline with only the `xla` crate's dependency
//! closure vendored, so the conveniences usually pulled from `clap`, `rand`,
//! `proptest` and `criterion` are implemented here from scratch (and unit-tested
//! like any other substrate module).

pub mod error;
pub mod rng;
pub mod hashing;
pub mod format;
pub mod csv;
pub mod args;
pub mod proptest;
pub mod bench;
pub mod stats;

pub use error::{Error, Result};
pub use rng::SplitMix64;
