//! Miniature property-testing harness (`proptest` is unavailable offline).
//!
//! Provides the 20% of proptest the suite needs: seeded generators, a `forall`
//! runner with a case budget, and on failure a greedy shrink loop over the
//! integer tuple inputs. Deterministic: failures reproduce from the printed
//! seed.

use crate::util::rng::SplitMix64;

/// Outcome of a property over one input.
pub type PropResult = std::result::Result<(), String>;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to execute.
    pub cases: usize,
    /// Base seed; case `i` uses `seed ^ i` forked.
    pub seed: u64,
    /// Maximum shrink iterations on failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FF_EE00, max_shrink: 512 }
    }
}

/// Run `prop` on `cases` random inputs drawn by `gen`; on failure, greedily
/// shrink the failing input with `shrink` (which proposes smaller candidates)
/// and panic with the minimal reproduction.
pub fn forall<T, G, S, P>(cfg: &Config, name: &str, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut SplitMix64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    for case in 0..cfg.cases {
        let mut rng = SplitMix64::new(cfg.seed ^ case as u64).fork(case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut budget = cfg.max_shrink;
            'outer: loop {
                for cand in shrink(&best) {
                    if budget == 0 {
                        break 'outer;
                    }
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property `{name}` failed (seed={:#x}, case={case})\n  minimal input: {best:?}\n  reason: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Standard shrinker for a pair of small positive integers: propose halving and
/// decrementing each coordinate toward `lo`.
pub fn shrink_pair(lo: i64) -> impl Fn(&(i64, i64)) -> Vec<(i64, i64)> {
    move |&(a, b)| {
        let mut out = Vec::new();
        for (na, nb) in [
            (lo + (a - lo) / 2, b),
            (a, lo + (b - lo) / 2),
            (a - 1, b),
            (a, b - 1),
        ] {
            if (na, nb) != (a, b) && na >= lo && nb >= lo {
                out.push((na, nb));
            }
        }
        out
    }
}

/// Convenience: assert two i64 values equal inside a property.
pub fn check_eq<T: PartialEq + std::fmt::Debug>(label: &str, got: T, want: T) -> PropResult {
    if got == want {
        Ok(())
    } else {
        Err(format!("{label}: got {got:?}, want {want:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // Interior mutability via Cell to count invocations.
        let counter = std::cell::Cell::new(0usize);
        forall(
            &Config { cases: 50, ..Default::default() },
            "trivially true",
            |rng| rng.range_i64(0, 100),
            |_| vec![],
            |_| {
                counter.set(counter.get() + 1);
                Ok(())
            },
        );
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_name() {
        forall(
            &Config { cases: 1, ..Default::default() },
            "always fails",
            |rng| rng.range_i64(0, 10),
            |_| vec![],
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrinker_finds_minimal_pair() {
        // Property "a + b < 10" fails first on some random (a,b) with a+b >= 10;
        // the shrinker should drive it down to a minimal counterexample whose
        // sum is exactly 10 (any smaller passes).
        let result = std::panic::catch_unwind(|| {
            forall(
                &Config { cases: 200, ..Default::default() },
                "sum below ten",
                |rng| (rng.range_i64(0, 64), rng.range_i64(0, 64)),
                shrink_pair(0),
                |&(a, b)| {
                    if a + b < 10 {
                        Ok(())
                    } else {
                        Err(format!("sum {}", a + b))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("sum 10"), "expected minimal sum 10, got: {msg}");
    }

    #[test]
    fn check_eq_formats_mismatch() {
        assert!(check_eq("x", 1, 1).is_ok());
        let e = check_eq("x", 1, 2).unwrap_err();
        assert!(e.contains("got 1"));
        assert!(e.contains("want 2"));
    }

    #[test]
    fn shrink_pair_respects_lower_bound() {
        let s = shrink_pair(3);
        for cand in s(&(4, 3)) {
            assert!(cand.0 >= 3 && cand.1 >= 3);
        }
        assert!(s(&(3, 3)).is_empty());
    }
}
