//! Deterministic PRNG: SplitMix64.
//!
//! Every stochastic element of the library (synthesis-jitter emulation, stimulus
//! generation, property tests, allocator tie-breaking) draws from this generator
//! so that runs are exactly reproducible from a seed. SplitMix64 is the standard
//! 64-bit mixer from Steele et al. (OOPSLA'14); it passes BigCrush when used as
//! a stream and is more than adequate for simulation noise.

/// SplitMix64 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Two generators with the same seed yield
    /// identical streams.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection-free
    /// approximation (bias < 2^-32 for bounds < 2^32, irrelevant here).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "bound must be positive");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.next_below(span) as i64
    }

    /// Uniform float in `[0, 1)` with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard-normal sample (Box–Muller, one value per call; the pair's second
    /// half is discarded to keep the generator stateless beyond `state`).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid ln(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child generator whose stream is independent of (but determined by)
    /// the parent's seed and the given label. Used to give each synthesis job a
    /// private stream regardless of scheduling order.
    pub fn fork(&self, label: u64) -> SplitMix64 {
        let mut probe = SplitMix64::new(self.state ^ label.rotate_left(17) ^ 0xA5A5_5A5A_DEAD_BEEF);
        // Burn one output so `fork(0)` differs from a plain clone.
        probe.next_u64();
        probe
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector_seed_zero() {
        // First outputs of splitmix64 with seed 0 (cross-checked against the
        // reference C implementation).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_i64_inclusive_and_covers() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = SplitMix64::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = SplitMix64::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forks_are_independent_streams() {
        let base = SplitMix64::new(21);
        let mut f0 = base.fork(0);
        let mut f1 = base.fork(1);
        let mut same = 0;
        for _ in 0..64 {
            if f0.next_u64() == f1.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0, "fork streams must diverge");
    }
}
