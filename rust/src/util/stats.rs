//! Shared latency statistics: the single nearest-rank percentile
//! implementation plus a lock-striped latency ring.
//!
//! Percentile math used to live in `coordinator::service` and was re-derived
//! ad hoc by the traffic simulator's roll-ups; PR 6 hoists it here so
//! `ServiceStats`, the fleetplan SLO tracker and the simulator all share one
//! definition (and one set of regression tests — see the ceiling-rank note
//! below).
//!
//! [`LatencyRing`] is the recording side: a bounded window of recent latency
//! samples built for the lock-free stats path (`docs/HOTPATH.md`). The
//! single writer (a service worker) round-robins samples across independently
//! locked stripes, so a reader summarizing the ring only ever contends with
//! the writer on one stripe at a time — the worker never blocks behind a
//! whole-window lock while a monitor aggregates.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Nearest-rank percentile over an ascending-sorted sample: the smallest
/// element with at least `pct`% of the sample at or below it, i.e. rank
/// ⌈n·pct/100⌉ (1-based). Returns 0 for an empty sample.
///
/// The ceiling is load-bearing: a floored rank `(n-1)·pct/100` reads *below*
/// the requested percentile for small n (at n = 2 it reports the minimum as
/// the p95 — the bug fixed in PR 2; see the regression test in
/// `coordinator::service`).
pub fn percentile_nearest_rank(sorted: &[u64], pct: u64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (sorted.len() as u64 * pct).div_ceil(100).max(1) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// Mean and nearest-rank p95 of an unsorted sample window, in the sample's
/// own unit (callers scale µs or ns to ms themselves). Returns `(0.0, 0)`
/// for an empty window.
pub fn window_mean_p95(samples: &[u64]) -> (f64, u64) {
    if samples.is_empty() {
        return (0.0, 0);
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    let mean = sorted.iter().sum::<u64>() as f64 / sorted.len() as f64;
    (mean, percentile_nearest_rank(&sorted, 95))
}

/// Stripes in a [`LatencyRing`]; a power of two so the cursor modulo is a
/// mask. 8 stripes keep the per-stripe critical section tiny while staying
/// cheap to concatenate on snapshot.
const STRIPES: usize = 8;

/// One stripe: a fixed-capacity overwrite ring of samples.
struct Stripe {
    samples: Vec<u64>,
    next: usize,
}

/// Bounded window of recent latency samples, striped over [`STRIPES`]
/// independent locks.
///
/// Writer side ([`LatencyRing::record`]): the owning worker advances a
/// relaxed atomic cursor and appends to `cursor % STRIPES`, overwriting the
/// stripe's oldest sample once full — so the ring as a whole retains the
/// most recent `window` samples (the striping preserves the plain ring's
/// eviction order because the writer visits stripes round-robin).
///
/// Reader side ([`LatencyRing::snapshot`]): locks stripes one at a time and
/// concatenates, so a snapshot never stalls the writer for more than one
/// stripe's critical section. Sample order across stripes is not
/// chronological; consumers sort anyway (see [`window_mean_p95`]).
pub struct LatencyRing {
    stripes: Vec<Mutex<Stripe>>,
    /// Round-robin write cursor. Relaxed: it only picks a stripe; the
    /// stripe mutex orders the sample data itself.
    cursor: AtomicUsize,
    stripe_cap: usize,
}

impl LatencyRing {
    /// Ring retaining the most recent `window` samples (rounded up to a
    /// multiple of the stripe count).
    pub fn new(window: usize) -> LatencyRing {
        let stripe_cap = window.div_ceil(STRIPES).max(1);
        LatencyRing {
            stripes: (0..STRIPES)
                .map(|_| Mutex::new(Stripe { samples: Vec::new(), next: 0 }))
                .collect(),
            cursor: AtomicUsize::new(0),
            stripe_cap,
        }
    }

    /// Record one sample, evicting the window's oldest once full.
    pub fn record(&self, sample: u64) {
        let at = self.cursor.fetch_add(1, Ordering::Relaxed);
        let mut stripe = self.stripes[at % STRIPES].lock().unwrap();
        if stripe.samples.len() < self.stripe_cap {
            stripe.samples.push(sample);
        } else {
            let slot = stripe.next;
            stripe.samples[slot] = sample;
        }
        stripe.next = (stripe.next + 1) % self.stripe_cap;
    }

    /// Samples currently retained (≤ the configured window).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().samples.len()).sum()
    }

    /// True when no sample has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the retained window (unsorted; stripe-interleaved order).
    pub fn snapshot(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.stripe_cap * STRIPES);
        for stripe in &self.stripes {
            out.extend_from_slice(&stripe.lock().unwrap().samples);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_matches_service_semantics() {
        let lats: Vec<u64> = (1..=10).collect();
        assert_eq!(percentile_nearest_rank(&lats, 95), 10);
        assert_eq!(percentile_nearest_rank(&lats, 50), 5);
        assert_eq!(percentile_nearest_rank(&lats, 100), 10);
        assert_eq!(percentile_nearest_rank(&[], 95), 0);
        assert_eq!(percentile_nearest_rank(&[7], 95), 7);
        assert_eq!(percentile_nearest_rank(&[3, 400], 95), 400);
    }

    #[test]
    fn window_summary_sorts_internally() {
        let (mean, p95) = window_mean_p95(&[400, 3]);
        assert!((mean - 201.5).abs() < 1e-9);
        assert_eq!(p95, 400, "p95 must come from the sorted window");
        assert_eq!(window_mean_p95(&[]), (0.0, 0));
    }

    #[test]
    fn ring_retains_exactly_the_most_recent_window() {
        // Same invariant the old single-vector ring was tested for: after
        // window + 100 inserts of 0..window+100, the 100 oldest samples are
        // gone and memory stays bounded — striping must not change eviction.
        let window = 4096u64;
        let ring = LatencyRing::new(window as usize);
        for i in 0..(window + 100) {
            ring.record(i);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), window as usize, "memory stays bounded");
        assert_eq!(*snap.iter().min().unwrap(), 100);
        assert_eq!(*snap.iter().max().unwrap(), window + 99);
    }

    #[test]
    fn ring_rounds_tiny_windows_up_to_the_stripe_count() {
        let ring = LatencyRing::new(1);
        assert!(ring.is_empty());
        for i in 0..100 {
            ring.record(i);
        }
        // One slot per stripe: the last STRIPES samples survive.
        let mut snap = ring.snapshot();
        snap.sort_unstable();
        assert_eq!(snap, (100 - STRIPES as u64..100).collect::<Vec<_>>());
    }

    #[test]
    fn ring_snapshot_is_safe_under_concurrent_recording() {
        use std::sync::Arc;
        let ring = Arc::new(LatencyRing::new(64));
        std::thread::scope(|scope| {
            let r = Arc::clone(&ring);
            let writer = scope.spawn(move || {
                for i in 0..10_000 {
                    r.record(i);
                }
            });
            for _ in 0..50 {
                let snap = ring.snapshot();
                assert!(snap.len() <= 64);
            }
            writer.join().unwrap();
        });
        assert_eq!(ring.len(), 64);
    }
}
