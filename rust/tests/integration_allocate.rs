//! Integration: the Table 5 allocation study on real fitted models, and its
//! cross-platform generalization.

use convkit::allocate::{allocate_mix, allocate_single, unit_costs};
use convkit::blocks::BlockKind;
use convkit::coordinator::dse::DseEngine;
use convkit::platform::Platform;

fn report() -> convkit::coordinator::dse::DseReport {
    DseEngine::new().run().unwrap()
}

#[test]
fn table5_shape_on_zcu104() {
    let rep = report();
    let rows = rep.allocation_study(&Platform::zcu104(), 8, 8, 0.8).unwrap();
    // Row order: mix, Conv1, Conv2, Conv3, Conv4.
    let mix = &rows[0].1;
    let single: Vec<u64> = (1..5).map(|i| rows[i].1.total_blocks()).collect();

    // DSP-bound singles are EXACT paper values (structural DSP counts):
    assert_eq!(single[1], 1382, "Conv2 row");
    assert_eq!(single[2], 1382, "Conv3 row");
    assert_eq!(single[3], 691, "Conv4 row");
    // Conv1 is fabric-bound in the low thousands (paper: 1770).
    assert!((800..=2500).contains(&single[0]), "Conv1 row {}", single[0]);
    // The strategy row beats every single row in delivered convolutions
    // (paper: 3564 vs 2764 best single).
    let best_single = [
        single[0],
        single[1],
        single[2] * 2,
        single[3] * 2,
    ]
    .into_iter()
    .max()
    .unwrap();
    assert!(
        mix.total_convolutions() > best_single,
        "mix {} vs best single {best_single}",
        mix.total_convolutions()
    );
    assert!((3000..=4500).contains(&mix.total_convolutions()), "{}", mix.total_convolutions());
}

#[test]
fn mix_always_respects_the_cap() {
    let rep = report();
    for platform in Platform::all() {
        for cap in [0.5, 0.8, 0.95] {
            let unit = unit_costs(&rep.registry, 8, 8).unwrap();
            let mix = allocate_mix(&unit, &platform, cap).unwrap();
            assert!(
                mix.usage(&unit).fits_within(&platform.capped_budget(cap)),
                "{} at {cap}",
                platform.name
            );
        }
    }
}

#[test]
fn dsp_utilization_saturates_at_the_cap() {
    // The mix row must drive DSPs to (just under) the cap — that is the
    // strategy the paper's first Table 5 row demonstrates (80.0% DSP).
    let rep = report();
    let platform = Platform::zcu104();
    let unit = unit_costs(&rep.registry, 8, 8).unwrap();
    let mix = allocate_mix(&unit, &platform, 0.8).unwrap();
    let u = platform.utilization(&mix.usage(&unit));
    assert!(u[4] > 78.0, "DSP utilization {:.1}%", u[4]);
    assert!(u.iter().all(|&x| x <= 80.0 + 1e-9), "{u:?}");
}

#[test]
fn bigger_devices_allocate_more() {
    let rep = report();
    let unit = unit_costs(&rep.registry, 8, 8).unwrap();
    let small = allocate_mix(&unit, &Platform::kv260(), 0.8).unwrap();
    let big = allocate_mix(&unit, &Platform::zcu111(), 0.8).unwrap();
    assert!(big.total_convolutions() > small.total_convolutions());
}

#[test]
fn precision_scaling_conv1_count_drops_with_width() {
    // Wider operands -> bigger Conv1 -> fewer instances under the same cap.
    let rep = report();
    let platform = Platform::zcu104();
    let n_at = |d: u32, c: u32| {
        let unit = unit_costs(&rep.registry, d, c).unwrap();
        allocate_single(&unit[0], &platform, 0.8)
    };
    assert!(n_at(4, 4) > n_at(8, 8));
    assert!(n_at(8, 8) > n_at(16, 16));
}

#[test]
fn conv3_single_row_unaffected_by_data_width() {
    // Conv3's fixed lanes: its allocation capacity is identical at d=4 and
    // d=8 (paper: every Conv3 resource has zero data correlation).
    let rep = report();
    let platform = Platform::zcu104();
    let at = |d: u32| {
        let unit = unit_costs(&rep.registry, d, 8).unwrap();
        allocate_single(&unit[2], &platform, 0.8)
    };
    assert_eq!(at(4), at(8));
}
