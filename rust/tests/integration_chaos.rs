//! Chaos + priority-tier integration: the live worker's weighted-fair drain
//! against the `wfq_schedule` reference interpreter, live/sim telemetry
//! parity under a wedged worker, the full fault-injection loop with the
//! production autoscaler in control, and the overload shed/starvation laws.
//!
//! These tests pin the contracts `simulate/chaos.rs` builds on: the sim is
//! only a trustworthy chaos rig because the live stack provably drains,
//! sheds, and emits spans the same way the virtual clock does.

use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{
    batch_queue_share, wfq_schedule, CoalescePolicy, Priority, Shard, ShardSpec,
};
use convkit::fleetplan::{Autoscaler, FleetPlan, NetworkPlan, SloPolicy};
use convkit::obs::Telemetry;
use convkit::platform::Platform;
use convkit::simulate::{
    run_chaos, Admission, ChaosFault, ChaosPlan, ChaosReport, Scenario, ScenarioShape, SimFleet,
    SimRunOptions, SimServiceModel, Trace,
};
use convkit::synth::ResourceVector;
use convkit::util::error::Result;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// A gated executor that records the first pixel of every image it serves,
/// in service order — the probe that makes the worker's WFQ drain order
/// observable. `entered` fires on entry to every batch so tests can
/// synchronize with the worker deterministically.
struct RecordingGatedExecutor {
    gate: mpsc::Receiver<()>,
    entered: mpsc::Sender<()>,
    seen: Arc<Mutex<Vec<i32>>>,
}

impl BatchExecutor for RecordingGatedExecutor {
    fn infer_batch(&mut self, images: &[Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        let _ = self.entered.send(());
        // A closed gate (test tore down early) just lets the batch through.
        let _ = self.gate.recv();
        let mut seen = self.seen.lock().unwrap();
        for im in images {
            seen.push(im[0]);
        }
        Ok(images.iter().map(|_| vec![0]).collect())
    }

    fn label(&self) -> String {
        "recording-gated".to_string()
    }
}

/// The live worker drains a mixed two-tier backlog in EXACTLY the order the
/// pure [`wfq_schedule`] reference interpreter predicts — the law the
/// simulator and the policy-search objectives assume.
///
/// Construction: batch size 1 makes every WFQ pick its own batch. A
/// batch-tier plug occupies the worker first (a batch-tier pick leaves the
/// deficit counters exactly at the fresh-state values the reference
/// interpreter starts from), the backlog accumulates behind it in the
/// channel, and releasing the gate drains it one pick per batch.
#[test]
fn live_worker_drains_a_mixed_backlog_in_wfq_reference_order() {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let svc = InferenceService::start(
        RecordingGatedExecutor {
            gate: gate_rx,
            entered: entered_tx,
            seen: Arc::clone(&seen),
        },
        1,
    );
    let shard = Shard::from_service("net", 0, 16, svc);

    // Batch-tier admission is capped at the share of the TOTAL outstanding
    // count, so the batch backlog must be in before interactive fills the
    // queue: plug + 3 batch requests stay under `batch_queue_share(16)`.
    assert_eq!(batch_queue_share(16), 4, "share law moved; rebuild this test's arithmetic");
    let plug = shard.try_submit_prioritized(vec![99], Priority::Batch).expect("plug admitted");
    entered_rx.recv().expect("worker entered the plug batch");
    let mut tickets = Vec::new();
    for v in [11, 12, 13] {
        tickets.push(shard.try_submit_prioritized(vec![v], Priority::Batch).expect("batch"));
    }
    for v in [1, 2, 3] {
        tickets.push(shard.try_submit_prioritized(vec![v], Priority::Interactive).expect("int"));
    }
    // One gate token per batch: 7 requests at batch size 1 = 7 batches.
    for _ in 0..7 {
        gate_tx.send(()).expect("worker alive");
    }
    plug.wait().expect("plug served");
    for t in tickets {
        t.wait().expect("backlog served");
    }
    shard.shutdown();

    let reference: Vec<i32> = wfq_schedule(&[vec![1, 2, 3], vec![11, 12, 13]])
        .into_iter()
        .map(|(_, v)| v)
        .collect();
    assert_eq!(
        reference,
        vec![1, 2, 3, 11, 12, 13],
        "reference interpreter pins the 3:1 replenish law"
    );
    let seen = seen.lock().unwrap().clone();
    assert_eq!(seen[0], 99, "plug batch must be served first");
    assert_eq!(
        &seen[1..],
        &reference[..],
        "live worker's drain order diverged from the wfq_schedule reference"
    );
}

/// A wedged worker must look identical on both planes: the live executor
/// blocked inside `infer_batch` and the simulator's wedged replica both
/// pile the same backlog into one recovery batch, emit the same per-kind
/// span counts through the shared [`Telemetry`] sink, and keep stats
/// readable mid-wedge (the flight recorder never blocks on a sick worker).
#[test]
fn wedged_worker_emits_identical_span_counts_live_and_simulated() {
    // --- live: one observed replica, wedged inside batch 1 of 1 request ---
    let live = Arc::new(Telemetry::new());
    let scope = live.scope_for("net", 0);
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let svc = InferenceService::start_factory_observed(
        move || Ok(RecordingGatedExecutor { gate: gate_rx, entered: entered_tx, seen }),
        8,
        CoalescePolicy::fixed(Duration::from_micros(100)),
        Some(scope.clone()),
    );
    let shard = Shard::from_service("net", 0, 16, svc).observed(scope);
    let first = shard.try_submit(vec![0]).expect("first admitted");
    entered_rx.recv().expect("worker wedged inside batch 1");
    let tickets: Vec<_> =
        (1..8).map(|k| shard.try_submit(vec![k]).expect("queued behind wedge")).collect();
    // Stats stay readable while the worker is wedged.
    let mid = shard.stats();
    assert_eq!(mid.queue_depth, 8, "8 outstanding while wedged");
    assert_eq!(mid.service.batches, 0, "no batch completed yet");
    gate_tx.send(()).expect("release batch 1");
    gate_tx.send(()).expect("release recovery batch");
    first.wait().expect("wedged request served");
    for t in tickets {
        t.wait().expect("backlog served after wake");
    }
    let live_stats = shard.stats();
    assert_eq!(live_stats.service.requests, 8);
    assert_eq!(live_stats.service.batches, 2, "wedge coalesces the backlog into [1, 7]");
    shard.shutdown();

    // --- sim: the same timeline on the virtual clock ---
    let sim = Arc::new(Telemetry::new());
    let mut sf = SimFleet::new(&[SimServiceModel::new("net", 1.0, 16, 1).with_batching(8, 0.1)])
        .expect("sim fleet");
    sf.set_telemetry(Arc::clone(&sim));
    assert!(matches!(sf.offer("net", 0).expect("offer"), Admission::Admitted { .. }));
    for k in 1u64..8 {
        let adm = sf.offer("net", k * 10_000).expect("offer");
        assert!(matches!(adm, Admission::Admitted { .. }), "arrival {k} rejected");
    }
    // Wedge past the in-flight completion (1 ms service): the first request
    // finishes on time, the 7 queued behind it defer to the 3 ms wake.
    assert!(sf.wedge_replica("net", 0, 3_000_000), "replica exists");
    sf.run_until(2_000_000);
    let mid = sf.stats();
    assert_eq!(mid.shards[0].queue_depth, 7, "stats stay instant while wedged");
    assert_eq!(mid.shards[0].service.requests, 1, "in-flight batch completed on time");
    sf.drain();
    let sim_stats = sf.stats();
    assert_eq!(sim_stats.shards[0].service.requests, 8);
    assert_eq!(sim_stats.shards[0].service.batches, 2, "same [1, 7] batch shape");

    let live_counts = live.span_kind_counts();
    let sim_counts = sim.span_kind_counts();
    assert_eq!(live_counts, sim_counts, "span timelines diverged under the wedge");
    assert_eq!(live_counts["window_open"], 2, "one window per batch on both planes");
    assert_eq!(live_counts["guard_release"], 8, "one release per request on both planes");
}

/// Two-network fleet plan for the e2e chaos run: a and b, floors at the
/// seeded replica counts so idle ticks never scale below the fault rig's
/// assumptions, headroom to 4 so overload recovery can scale up.
fn chaos_scaler_plan() -> FleetPlan {
    let platform = Platform::zcu104();
    let unit = ResourceVector::new(1_000, 0, 0, 0, 100);
    let net = |name: &str| NetworkPlan {
        network: name.to_string(),
        unit,
        predicted_ms: 0.5,
        fill_ms: 0.1,
        util_frac: 100.0 / 1382.0,
        replicas: 2,
        min_replicas: 2,
        max_replicas: 4,
        weight: 1.0,
    };
    FleetPlan {
        platform: platform.clone(),
        cap: 0.8,
        networks: vec![net("a"), net("b")],
        total: unit.scaled(4),
        utilization: platform.utilization(&unit.scaled(4)),
    }
}

fn chaos_fleet() -> SimFleet {
    SimFleet::new(&[
        SimServiceModel::new("a", 0.5, 8, 2).on_platform("dev0", 0.2),
        SimServiceModel::new("b", 0.5, 8, 2).on_platform("dev1", 0.2),
    ])
    .expect("two-device fleet")
}

fn chaos_trace() -> Trace {
    Scenario::new(
        ScenarioShape::Steady,
        vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
        800.0,
        100.0,
        42,
    )
    .arrivals()
}

/// All five fault classes on one timeline, with the device failure paired
/// with a rebind so the dead network comes back — the controller only sees
/// networks that report SLO rows, so an unrebound device is unrecoverable
/// by design and would (correctly) fail the recovery assertion.
fn chaos_full_plan() -> ChaosPlan {
    ChaosPlan::new(0xC0FFEE, 0.10)
        .with_fault(ChaosFault::WedgeReplica {
            at_ms: 20.0,
            network: "a".to_string(),
            ordinal: 0,
            stall_ms: 15.0,
        })
        .with_fault(ChaosFault::KillReplica { at_ms: 30.0, network: "b".to_string() })
        .with_fault(ChaosFault::FailDevice { at_ms: 50.0, device: "dev0".to_string() })
        .with_fault(ChaosFault::RebindDevice {
            at_ms: 58.0,
            device: "dev0".to_string(),
            network: "a".to_string(),
            replicas: 2,
            downtime_ms: 4.0,
        })
        .with_fault(ChaosFault::BurstStorm { at_ms: 70.0, len_ms: 15.0, factor: 2 })
}

fn run_e2e_chaos(trace: &Trace) -> ChaosReport {
    let policy = SloPolicy { window: 1, ..SloPolicy::default() };
    let templates = vec![
        ShardSpec::golden("a").with_queue_cap(8),
        ShardSpec::golden("b").with_queue_cap(8),
    ];
    let mut scalers = [Autoscaler::new(chaos_scaler_plan(), policy.clone(), templates)];
    let opts = SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 8 };
    let mut fleet = chaos_fleet();
    run_chaos(&mut fleet, trace, &mut scalers, &policy, &chaos_full_plan(), &opts)
        .expect("chaos run")
}

/// The whole loop, end to end: every fault class injected against the
/// PRODUCTION [`Autoscaler`], every fault recovered within a handful of
/// control ticks, conservation intact, no interactive request ever shed —
/// and the entire report a pure function of its inputs (two fresh runs are
/// byte-identical, which is what lets CI diff archived chaos reports).
#[test]
fn chaos_run_with_production_autoscaler_recovers_every_fault_deterministically() {
    let trace = chaos_trace();
    let one = run_e2e_chaos(&trace);
    let two = run_e2e_chaos(&trace);
    assert_eq!(one.to_json(), two.to_json(), "chaos report must be byte-deterministic");

    assert!(one.conserved, "offered == completed + rejected + shed per network per tier");
    assert_eq!(one.admitted, one.completed, "drained run completes everything it admitted");
    assert_eq!(
        one.shed_tier[Priority::Interactive.index()],
        0,
        "interactive work is never shed"
    );
    assert_eq!(one.faults.len(), 5, "all five fault classes injected");
    for f in &one.faults {
        assert!(f.recovered, "fault `{}` never left Overloaded: {:?}", f.label, one.faults);
    }
    let bound = 6.0 * 5.0;
    assert!(
        one.worst_recovery_ms() <= bound,
        "worst recovery {:.1} ms exceeds {bound} ms (6 control ticks)",
        one.worst_recovery_ms()
    );
    // The storm window amplifies arrivals beyond the base trace.
    assert!(
        one.offered > trace.len() as u64,
        "storm should amplify offered load: {} offered vs {} traced",
        one.offered,
        trace.len()
    );
    let fairness = one.tier_fairness();
    assert!(
        fairness > 0.0 && fairness <= 1.0,
        "fairness must be a capped completion-rate ratio, got {fairness}"
    );
}

/// Sustained 2x overload with a 90/10 interactive/batch mix: overload
/// protection sheds batch (never interactive), rejects interactive (never
/// batch), and interactive's completion rate stays at least batch's — while
/// the pure WFQ law still guarantees batch its 1-in-4 drain share for as
/// long as both tiers are backlogged (the anti-starvation floor).
#[test]
fn overload_sheds_batch_first_but_wfq_floors_its_drain_share() {
    let mut fleet =
        SimFleet::new(&[SimServiceModel::new("hot", 1.0, 8, 1)]).expect("single hot replica");
    let trace = Scenario::new(
        ScenarioShape::Steady,
        vec![("hot".to_string(), 1.0)],
        2_000.0,
        200.0,
        7,
    )
    .arrivals();
    let policy = SloPolicy::default();
    let opts = SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 2 };
    let mut scalers: [Autoscaler; 0] = [];
    let plan = ChaosPlan::new(0xFA1, 0.10);
    let r = run_chaos(&mut fleet, &trace, &mut scalers, &policy, &plan, &opts)
        .expect("overload run");

    let i = Priority::Interactive.index();
    let b = Priority::Batch.index();
    assert!(r.conserved, "conservation must survive sustained overload");
    assert_eq!(r.offered, trace.len() as u64, "no storm: offered == traced");
    assert_eq!(r.shed_tier[i], 0, "interactive is never shed");
    assert_eq!(r.rejected_tier[b], 0, "batch is shed, never rejected");
    assert!(r.shed_tier[b] > 0, "2x overload must shed batch work");
    assert!(r.rejected_tier[i] > 0, "2x overload must reject interactive past cap");
    assert!(r.completed_tier[b] > 0, "admitted batch work still completes");
    // Interactive protection: its completion rate >= batch's (cross-
    // multiplied to stay in integers).
    assert!(
        r.completed_tier[i] * r.offered_tier[b] >= r.completed_tier[b] * r.offered_tier[i],
        "interactive completion rate fell below batch under overload: {:?} / {:?}",
        r.completed_tier,
        r.offered_tier
    );
    assert_eq!(r.scale_ups + r.scale_downs, 0, "no controllers attached");

    // The anti-starvation floor, straight from the reference interpreter:
    // with 90 interactive and 10 batch requests backlogged, batch holds its
    // 1-in-4 pick share (weights 3:1) until its queue empties at pick 40.
    let interactive: Vec<u32> = (0..90).collect();
    let batch: Vec<u32> = (0..10).collect();
    let order = wfq_schedule(&[interactive, batch]);
    assert_eq!(order.len(), 100);
    for (k, (tier, _)) in order.iter().enumerate().take(40) {
        let expect = if k % 4 == 3 { Priority::Batch } else { Priority::Interactive };
        assert_eq!(*tier, expect, "pick {k} broke the 3:1 cadence");
    }
    assert!(
        order.iter().skip(40).all(|(t, _)| *t == Priority::Interactive),
        "batch queue empties after its 10th pick; the tail is all interactive"
    );
}
