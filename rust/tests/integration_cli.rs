//! CLI smoke tests: run the built binary end to end (no PJRT-dependent
//! subcommands here; those are covered by integration_runtime + the serve
//! command inside e2e_pipeline).

use std::process::Command;

fn convkit(args: &[&str]) -> (bool, String, String) {
    let exe = env!("CARGO_BIN_EXE_convkit");
    let out = Command::new(exe).args(args).output().expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_prints_usage() {
    let (ok, stdout, _) = convkit(&["help"]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("allocate"));
}

#[test]
fn unknown_command_fails_with_usage_hint() {
    let (ok, _, stderr) = convkit(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn blocks_prints_table2() {
    let (ok, stdout, _) = convkit(&["blocks"]);
    assert!(ok);
    for b in ["Conv1", "Conv2", "Conv3", "Conv4", "Conv2Act"] {
        assert!(stdout.contains(b));
    }
}

#[test]
fn sweep_small_range_reports_counts() {
    let (ok, stdout, stderr) = convkit(&["sweep", "--min-bits", "6", "--max-bits", "9"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("synthesized 80 configurations"), "{stdout}");
}

#[test]
fn correlate_small_prints_quadrants() {
    let (ok, stdout, _) = convkit(&["correlate", "--min-bits", "6", "--max-bits", "10"]);
    assert!(ok);
    assert!(stdout.contains("TABLE 3"));
    assert!(stdout.contains("Conv3"));
}

#[test]
fn fit_small_prints_models() {
    let (ok, stdout, _) = convkit(&["fit", "--min-bits", "6", "--max-bits", "12"]);
    assert!(ok);
    assert!(stdout.contains("TABLE 4"));
    assert!(stdout.contains("All fitted models"));
}

#[test]
fn predict_compares_model_and_synthesis() {
    let (ok, stdout, _) = convkit(&[
        "predict",
        "--block",
        "conv4",
        "--data-bits",
        "8",
        "--coeff-bits",
        "8",
        "--min-bits",
        "6",
        "--max-bits",
        "12",
    ]);
    assert!(ok);
    assert!(stdout.contains("model prediction"));
    assert!(stdout.contains("synthesis"));
}

#[test]
fn allocate_prints_table5() {
    let (ok, stdout, _) =
        convkit(&["allocate", "--min-bits", "6", "--max-bits", "12", "--target", "0.8"]);
    assert!(ok);
    assert!(stdout.contains("TABLE 5"));
    assert!(stdout.contains("Total Conv."));
}

#[test]
fn tables_1_and_2_need_no_sweep() {
    let (ok, stdout, _) = convkit(&["tables", "1"]);
    assert!(ok);
    assert!(stdout.contains("YOLOv2-Tiny"));
    let (ok, stdout, _) = convkit(&["tables", "2", "--french"]);
    assert!(ok);
    assert!(stdout.contains("Caractéristiques"));
}

#[test]
fn figures_render_ascii_surface() {
    let (ok, stdout, _) = convkit(&["figures", "2", "--min-bits", "6", "--max-bits", "12"]);
    assert!(ok);
    assert!(stdout.contains("FIGURE 2"));
    assert!(stdout.contains("R²"));
}

#[test]
fn figures_csv_mode() {
    let (ok, stdout, _) =
        convkit(&["figures", "3", "--csv", "--min-bits", "6", "--max-bits", "12"]);
    assert!(ok);
    assert!(stdout.contains("data_bits,coeff_bits,llut_measured,llut_fitted"));
}

#[test]
fn deploy_plans_lenet() {
    let (ok, stdout, _) = convkit(&[
        "deploy",
        "--network",
        "lenet_q8",
        "--min-bits",
        "6",
        "--max-bits",
        "12",
    ]);
    assert!(ok);
    assert!(stdout.contains("deployment plan"));
    assert!(stdout.contains("fits: true"));
}

#[test]
fn autoscale_demonstrates_model_driven_scale_up_and_down() {
    let (ok, stdout, stderr) = convkit(&[
        "autoscale",
        "--networks",
        "tiny_q8",
        "--min-bits",
        "6",
        "--max-bits",
        "12",
        "--requests",
        "64",
        "--rounds",
        "2",
        "--queue-cap",
        "2",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("capacity plan"), "{stdout}");
    assert!(stdout.contains("platform ceiling"), "{stdout}");
    // A pipelined 64-request burst against a cap-2 replica must overload it
    // (the worker cannot complete anything inside the coalescing window),
    // and the controller must answer with a justified, budgeted scale-up.
    assert!(stdout.contains("scale-up tiny_q8"), "{stdout}");
    assert!(stdout.contains("predicted fleet util"), "{stdout}");
    // The idle phase drains at least one replica back down.
    assert!(stdout.contains("scale-down tiny_q8"), "{stdout}");
    assert!(stdout.contains("autoscale summary"), "{stdout}");
}

#[test]
fn simulate_emits_a_deterministic_capacity_report() {
    let run = || {
        convkit(&[
            "simulate",
            "--scenario",
            "burst",
            "--seed",
            "42",
            "--networks",
            "tiny_q8",
            "--min-bits",
            "6",
            "--max-bits",
            "12",
            "--events",
            "5000",
            "--control-ms",
            "0.5",
        ])
    };
    let (ok, stdout, stderr) = run();
    assert!(ok, "{stderr}");
    assert!(stdout.contains("what-if capacity report"), "{stdout}");
    assert!(stdout.contains("scenario `burst`"), "{stdout}");
    assert!(stdout.contains("max sustainable"), "{stdout}");
    assert!(stdout.contains("tiny_q8"), "{stdout}");
    assert!(stdout.contains("replica trajectory"), "{stdout}");
    assert!(stdout.contains("virtual events"), "{stdout}");
    // Determinism across whole processes: the virtual-time report block is
    // identical (only the wall-clock timing line may differ).
    let (ok2, stdout2, _) = run();
    assert!(ok2);
    let report = |s: &str| {
        s.lines()
            .skip_while(|l| !l.contains("what-if capacity report"))
            .take_while(|l| !l.contains("s wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(report(&stdout), report(&stdout2), "same seed ⇒ same report");
    assert!(!report(&stdout).is_empty());
}

#[test]
fn policysearch_emits_a_deterministic_pareto_report() {
    let run = || {
        convkit(&[
            "policysearch",
            "--scenario",
            "burst",
            "--seed",
            "42",
            "--networks",
            "tiny_q8",
            "--min-bits",
            "6",
            "--max-bits",
            "12",
            "--events",
            "3000",
            "--control-ms",
            "0.5",
            "--overload",
            "0.005,0.05",
            "--p95-ratio",
            "3",
            "--idle-queue",
            "0.05",
            "--window",
            "2",
        ])
    };
    let (ok, stdout, stderr) = run();
    assert!(ok, "{stderr}");
    assert!(stdout.contains("SLO policy search"), "{stdout}");
    assert!(stdout.contains("grid: 2 policies"), "{stdout}");
    assert!(stdout.contains("Pareto front:"), "{stdout}");
    // Determinism across whole processes (only the wall line may differ).
    let (ok2, stdout2, _) = run();
    assert!(ok2);
    let report = |s: &str| {
        s.lines()
            .skip_while(|l| !l.contains("SLO policy search"))
            .take_while(|l| !l.contains("s wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(report(&stdout), report(&stdout2), "same seed ⇒ same report");
    assert!(!report(&stdout).is_empty());
}

#[test]
fn bad_option_value_is_a_usage_error() {
    let (ok, _, stderr) = convkit(&["sweep", "--min-bits", "banana"]);
    assert!(!ok);
    assert!(stderr.contains("integer"));
}
