//! Integration tests closing the telemetry loop end to end, pinning the
//! contracts `obs::drift` and `obs::trace` promise:
//!
//! 1. **Drift isolation** — a fleet whose engine runs a contention slope the
//!    monitor does not assume flags the contention model ONLY (latency and
//!    fill stay clean because the residual divides out the re-fitted
//!    stretch), re-fits the true slope within 10%, journals exactly one
//!    `ModelDrift` event, and arms exactly one flight dump. A correctly
//!    calibrated fleet raises no flags at all.
//! 2. **Trace completeness** — every admitted request reassembles into
//!    exactly one complete [`RequestTrace`](convkit::obs::RequestTrace) on
//!    both planes: the simulated fleet's per-replica rings and a live gated
//!    worker whose admissions pile up before any batch runs.
//! 3. **Live/sim parity** — a deliberately wrong latency prediction flags
//!    `MODEL_LATENCY` and nothing else on BOTH planes, with identical model
//!    rows in identical order; and the simulated drift report is
//!    byte-deterministic across runs of the same scenario.

use convkit::cnn::zoo;
use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{CoalescePolicy, Shard, ShardSpec, ShardedService};
use convkit::obs::{
    assemble, DriftMonitor, DriftReport, JournalKind, ModelExpectation, Telemetry,
    MODEL_CONTENTION, MODEL_LATENCY,
};
use convkit::simulate::{Admission, SimFleet, SimServiceModel, DEFAULT_CONTENTION_ALPHA};
use convkit::util::error::Result;
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// The contention slope the demo engine really runs with. At x = 0.3 the
/// stretch is exactly ×2.2 = ×11/5, and every base batch time below is a
/// multiple of 200 000 ns, so the stretched times are exact integers and
/// the re-fit recovers the slope to float precision.
const TRUE_ALPHA: f64 = 4.0;

/// Drive the mis-calibration demo on the virtual clock: two `hot` replicas
/// co-located on one device (util 0.3 each → x = 0.3) under a contention
/// slope of `true_alpha`, plus an un-colocated `lone` control network, then
/// score the run against a monitor assuming `assumed_alpha`. Returns the
/// report, the telemetry plane, and how many offers were admitted.
fn contended_sim_report(
    true_alpha: f64,
    assumed_alpha: f64,
) -> (DriftReport, Arc<Telemetry>, usize) {
    let models = [
        SimServiceModel::new("hot", 1.0, 8, 2).with_batching(4, 0.4).on_platform("fpga0", 0.3),
        SimServiceModel::new("lone", 0.5, 8, 1).with_batching(4, 0.2),
    ];
    let mut fleet = SimFleet::new(&models).expect("sim fleet");
    fleet.set_contention_alpha(true_alpha);
    let obs = Arc::new(Telemetry::new());
    fleet.set_telemetry(Arc::clone(&obs));
    // `hot` every 0.5 ms (sustained overload against its stretched service
    // rate, so queues churn and batch sizes vary), `lone` every 1 ms (always
    // idle on arrival, so its observations match its model exactly).
    let mut admitted = 0usize;
    for i in 0..400u64 {
        let at = i * 500_000;
        if matches!(fleet.offer("hot", at).expect("offer hot"), Admission::Admitted { .. }) {
            admitted += 1;
        }
        if i % 2 == 0
            && matches!(fleet.offer("lone", at).expect("offer lone"), Admission::Admitted { .. })
        {
            admitted += 1;
        }
    }
    fleet.drain();
    let mut monitor = DriftMonitor::new(fleet.drift_expectations(assumed_alpha));
    let report = monitor.report(&obs, fleet.now_ms());
    (report, obs, admitted)
}

/// The e2e acceptance demo: an engine running α=4.0 scored by a monitor
/// assuming the shipped 2.07 must flag the contention model of the
/// co-located network — and ONLY that model — re-fit the true slope within
/// 10%, journal the breach once, and arm one flight dump.
#[test]
fn a_miscalibrated_alpha_flags_contention_only_and_refits_the_true_slope() {
    let (report, obs, _) = contended_sim_report(TRUE_ALPHA, DEFAULT_CONTENTION_ALPHA);

    assert_eq!(
        report.flagged(),
        vec![("hot".to_string(), vec![MODEL_CONTENTION])],
        "the wrong slope must surface as contention drift on `hot` and nothing else"
    );
    let hot = report.networks.iter().find(|n| n.network == "hot").expect("hot scored");
    let fitted = hot.alpha_fitted.expect("co-located replicas yield a contention signal");
    assert!(
        (fitted - TRUE_ALPHA).abs() / TRUE_ALPHA <= 0.10,
        "re-fit α {fitted} not within 10% of the true {TRUE_ALPHA}"
    );
    let proposed = report.proposed_alpha.expect("flagged contention proposes a slope");
    assert!(
        (proposed - TRUE_ALPHA).abs() / TRUE_ALPHA <= 0.10,
        "proposed α {proposed} not within 10% of the true {TRUE_ALPHA}"
    );
    let lone = report.networks.iter().find(|n| n.network == "lone").expect("lone scored");
    assert!(
        lone.models.iter().all(|m| !m.flagged),
        "the un-colocated control network must stay clean"
    );

    // The watchdog's side effects: one journaled breach, one armed dump.
    let drift_events: Vec<_> = obs
        .journal()
        .snapshot()
        .into_iter()
        .filter(|e| e.kind == JournalKind::ModelDrift)
        .collect();
    assert_eq!(drift_events.len(), 1, "one (network, component) breach → one journal event");
    assert_eq!(drift_events[0].network, "hot");
    assert_eq!(obs.take_flights().len(), 1, "the breach arms exactly one flight dump");
    assert_eq!(report.spans_dropped, 0, "this run must fit the default rings");
}

/// A fleet whose assumed slope matches the engine raises no flags: no
/// journal events, no flight dumps, no proposed recalibration.
#[test]
fn a_correctly_calibrated_fleet_raises_no_flags() {
    let (report, obs, _) = contended_sim_report(TRUE_ALPHA, TRUE_ALPHA);
    assert!(report.flagged().is_empty(), "nothing drifts when the models are right");
    assert!(report.proposed_alpha.is_none(), "no drift, no recalibration proposal");
    let drift_events = obs
        .journal()
        .snapshot()
        .iter()
        .filter(|e| e.kind == JournalKind::ModelDrift)
        .count();
    assert_eq!(drift_events, 0);
    assert!(obs.take_flights().is_empty(), "nothing breached, nothing dumped");
}

/// Every admitted simulated request reassembles into exactly one complete
/// trace: per-replica rings fold with zero orphans, zero in-flight leftovers
/// and zero double counts, trace ids are unique fleet-wide, and each
/// trace's end-to-end residency bounds its exec time.
#[test]
fn every_admitted_sim_request_reassembles_into_one_complete_trace() {
    let (report, obs, admitted) = contended_sim_report(TRUE_ALPHA, DEFAULT_CONTENTION_ALPHA);
    assert_eq!(report.spans_dropped, 0, "assembly completeness needs a lossless ring");
    assert!(admitted > 0, "the scenario must admit traffic");

    let mut complete = 0usize;
    let mut ids = std::collections::BTreeSet::new();
    for (network, replica, events) in obs.ring_snapshots() {
        let asm = assemble(&events);
        assert_eq!(asm.orphaned, 0, "{network}/{replica}: no drops, so no orphans");
        assert_eq!(asm.incomplete, 0, "{network}/{replica}: a drained fleet leaves nothing open");
        assert_eq!(asm.double_counted, 0, "{network}/{replica}: ids never assemble twice");
        for t in &asm.complete {
            assert_ne!(t.trace, 0, "complete traces are never untraced");
            assert!(ids.insert(t.trace), "trace id {} appeared on two requests", t.trace);
            assert!(t.batch >= 1, "every trace rode a real batch");
            assert!(
                t.total_ns >= t.exec_ns,
                "{network}/{replica}: residency {} ns below exec {} ns",
                t.total_ns,
                t.exec_ns
            );
        }
        complete += asm.complete.len();
    }
    assert_eq!(complete, admitted, "every admitted request must reassemble exactly once");
}

/// An executor that refuses to run a batch until the test releases it, so
/// admissions (and their trace-carrying spans) pile up against a wedged
/// worker before any batch forms.
struct GatedExecutor {
    gate: mpsc::Receiver<()>,
}

impl BatchExecutor for GatedExecutor {
    fn infer_batch(&mut self, images: &[Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        // A closed gate (test ended early) just lets the batch through.
        let _ = self.gate.recv();
        Ok(images.iter().map(|im| vec![im.len() as i32]).collect())
    }

    fn label(&self) -> String {
        "gated".to_string()
    }
}

/// Live-plane assembly under the nastiest interleaving the coordinator
/// produces: all requests admitted while the worker is wedged inside a
/// batch, then released to coalesce however the worker pleases. However the
/// batching lands, every request must still reassemble exactly once.
#[test]
fn a_gated_live_worker_reassembles_every_request() {
    const REQUESTS: u64 = 8;

    let obs = Arc::new(Telemetry::new());
    let scope = obs.scope_for("gated", 0);
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let service = InferenceService::start_factory_observed(
        move || Ok(GatedExecutor { gate: gate_rx }),
        4,
        CoalescePolicy::fixed(Duration::from_micros(100)),
        Some(scope.clone()),
    );
    let shard = Shard::from_service("gated", 0, 16, service).observed(scope);

    let img: Arc<[i32]> = vec![1, 2, 3].into();
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|_| shard.submit(Arc::clone(&img)).expect("uncapped admission"))
        .collect();
    for _ in 0..REQUESTS {
        gate_tx.send(()).expect("worker alive");
    }
    for t in tickets {
        t.wait().expect("request served");
    }
    // Join the worker before snapshotting so every GuardRelease committed.
    shard.shutdown();

    let rings = obs.ring_snapshots();
    assert_eq!(rings.len(), 1, "one shard, one ring");
    let asm = assemble(&rings[0].2);
    assert_eq!(asm.complete.len(), REQUESTS as usize, "all {REQUESTS} requests reassemble");
    assert_eq!(
        (asm.orphaned, asm.incomplete, asm.double_counted),
        (0, 0, 0),
        "a lossless shut-down ring accounts for everything"
    );
    let mut ids = std::collections::BTreeSet::new();
    for t in &asm.complete {
        assert_ne!(t.trace, 0);
        assert!(ids.insert(t.trace), "trace id {} appeared on two requests", t.trace);
        assert!(t.release_t_ns >= t.enqueue_t_ns);
        assert!(t.total_ns >= t.exec_ns, "residency must bound exec for queued riders");
    }
}

/// The wrong latency expectation both planes are scored against: a 1 ns
/// service prediction no real (or simulated) batch can meet. `fill_ns = 0`
/// and `contention_x = 0` leave those rows unscored, so ONLY the latency
/// model can flag — which is exactly the isolation being tested.
fn wrong_latency_expectation() -> Vec<ModelExpectation> {
    vec![ModelExpectation {
        network: "tiny_q8".to_string(),
        service_ns: 1,
        fill_ns: 0,
        contention_x: 0.0,
        alpha: DEFAULT_CONTENTION_ALPHA,
    }]
}

/// Injecting a wrong `predicted_ms` must flag the latency model — and only
/// it — identically on the live and simulated planes: same flagged set,
/// same model rows in the same order, same sample counts.
#[test]
fn a_wrong_latency_prediction_flags_that_model_alone_on_both_planes() {
    const N: usize = 24;

    // Live: one golden-backed observed replica, strictly sequential client.
    let live = Arc::new(Telemetry::new());
    let fleet = ShardedService::start_observed(
        &[ShardSpec::golden("tiny_q8").with_batch_size(8)],
        Arc::clone(&live),
    )
    .expect("observed fleet start");
    let imgs: Vec<Arc<[i32]>> =
        zoo::tiny().synthetic_images_i32(4, 0xB0).into_iter().map(Into::into).collect();
    for k in 0..N {
        fleet
            .infer("tiny_q8", Arc::clone(&imgs[k % imgs.len()]))
            .expect("live inference");
    }
    fleet.shutdown();
    let mut live_monitor = DriftMonitor::new(wrong_latency_expectation());
    let live_report = live_monitor.report(&live, 0.0);

    // Sim: the same shape on the virtual clock.
    let sim = Arc::new(Telemetry::new());
    let mut sf =
        SimFleet::new(&[SimServiceModel::new("tiny_q8", 0.01, 8, 1)]).expect("sim fleet");
    sf.set_telemetry(Arc::clone(&sim));
    for k in 0..N as u64 {
        let adm = sf.offer("tiny_q8", (k + 1) * 1_000_000).expect("offer");
        assert!(matches!(adm, Admission::Admitted { .. }), "arrival {k} rejected");
    }
    sf.drain();
    let mut sim_monitor = DriftMonitor::new(wrong_latency_expectation());
    let sim_report = sim_monitor.report(&sim, sf.now_ms());

    for (plane, report) in [("live", &live_report), ("sim", &sim_report)] {
        assert_eq!(
            report.flagged(),
            vec![("tiny_q8".to_string(), vec![MODEL_LATENCY])],
            "{plane} plane must pin the bad prediction to the latency row alone"
        );
        let latency = report.networks[0]
            .models
            .iter()
            .find(|m| m.model == MODEL_LATENCY)
            .expect("latency row present");
        assert_eq!(latency.samples, N as u64, "{plane}: one batch per sequential request");
        assert!(latency.mpe > 0.0, "{plane}: real batches run LONGER than 1 ns");
    }
    let rows = |r: &DriftReport| {
        r.networks
            .iter()
            .map(|n| (n.network.clone(), n.models.iter().map(|m| m.model).collect::<Vec<_>>()))
            .collect::<Vec<_>>()
    };
    assert_eq!(
        rows(&live_report),
        rows(&sim_report),
        "both planes emit the same model rows in the same order"
    );
}

/// Two runs of the identical scenario on the virtual clock serialize to the
/// identical drift report, byte for byte — the property CI's archived
/// `DRIFT_report.json` diff relies on.
#[test]
fn the_sim_drift_report_is_byte_deterministic() {
    let (a, _, _) = contended_sim_report(TRUE_ALPHA, DEFAULT_CONTENTION_ALPHA);
    let (b, _, _) = contended_sim_report(TRUE_ALPHA, DEFAULT_CONTENTION_ALPHA);
    assert_eq!(a.to_json(), b.to_json(), "virtual-clock drift reports must reproduce exactly");
    assert_eq!(a, b, "and the structured reports must agree field for field");
}
