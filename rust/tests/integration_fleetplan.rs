//! Integration tests for the fleetplan subsystem against a LIVE fleet:
//! a deterministic load spike that triggers a model-budgeted scale-up, an
//! idle window that triggers a drain-based scale-down, and the drain
//! guarantee itself (a removal never loses an in-flight ticket).
//!
//! Determinism technique: overload is manufactured with a *gated* executor —
//! a worker that blocks until the test releases it — so admission rejections
//! are exact counts, not races. The scaled-up replica is a real golden one,
//! so the post-scale serving path is cross-checked bit-for-bit.

use convkit::blocks::BlockKind;
use convkit::cnn::{zoo, GoldenCnn};
use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{Shard, ShardSpec, ShardedService};
use convkit::fleetplan::{plan_fleet, Autoscaler, NetworkDemand, ScaleAction, SloPolicy};
use convkit::models::{ModelRegistry, SelectOptions};
use convkit::platform::Platform;
use convkit::synthdata::{run_sweep, SweepOptions};
use convkit::util::error::{Error, Result};
use std::sync::mpsc;
use std::time::Duration;

/// Executes one batch per token received on `gate`; blocks otherwise.
struct GatedExecutor {
    gate: mpsc::Receiver<()>,
    classes: usize,
}

impl BatchExecutor for GatedExecutor {
    fn infer_batch(&mut self, images: &[std::sync::Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        self.gate.recv().map_err(|_| Error::Runtime("gate closed".into()))?;
        Ok(images.iter().map(|_| vec![0i32; self.classes]).collect())
    }

    fn label(&self) -> String {
        "gated".into()
    }
}

fn gated_shard(network: &str, replica: usize, cap: usize) -> (Shard, mpsc::Sender<()>) {
    let (gate_tx, gate_rx) = mpsc::channel();
    let svc = InferenceService::start(GatedExecutor { gate: gate_rx, classes: 3 }, 1);
    (Shard::from_service(network, replica, cap, svc), gate_tx)
}

fn small_registry() -> ModelRegistry {
    let opts = SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() };
    let ds = run_sweep(&opts).unwrap();
    ModelRegistry::fit(&ds, &SelectOptions::default()).unwrap()
}

#[test]
fn add_and_remove_shard_reconfigure_routing_live() {
    let fleet = ShardedService::start(&[ShardSpec::golden("tiny_q8").with_batch_size(4)])
        .unwrap();
    assert_eq!(fleet.replica_count("tiny_q8"), 1);

    // Grow: the new replica gets the next ordinal and serves correctly.
    let spec = ShardSpec::golden("tiny_q8").with_batch_size(4);
    assert_eq!(fleet.add_shard(&spec).unwrap(), 1);
    assert_eq!(fleet.replica_count("tiny_q8"), 2);
    let tiny = zoo::tiny();
    let golden = GoldenCnn::new(tiny.clone(), BlockKind::Conv2).unwrap();
    for seed in 0..4u64 {
        let img = tiny.synthetic_images_i32(1, seed).pop().unwrap();
        let got = fleet.infer("tiny_q8", img.clone()).unwrap();
        let want: Vec<i32> = golden
            .infer(&img.iter().map(|&v| v as i64).collect::<Vec<_>>())
            .unwrap()
            .into_iter()
            .map(|v| v as i32)
            .collect();
        assert_eq!(got, want, "seed {seed}");
    }

    // Shrink: highest ordinal goes first; the network keeps serving.
    assert_eq!(fleet.remove_shard("tiny_q8").unwrap(), 1);
    assert_eq!(fleet.replica_count("tiny_q8"), 1);
    assert!(fleet.infer("tiny_q8", tiny.synthetic_images_i32(1, 9).pop().unwrap()).is_ok());

    // Guards: never below one replica, unknown networks rejected.
    assert!(matches!(fleet.remove_shard("tiny_q8"), Err(Error::InvalidConfig(_))));
    assert!(matches!(fleet.remove_shard("ghost"), Err(Error::Usage(_))));
    assert!(fleet.add_shard(&ShardSpec::golden("ghost")).is_err());
    fleet.shutdown();
}

#[test]
fn remove_shard_drains_in_flight_tickets_instead_of_dropping_them() {
    // Two gated replicas; replica 1 (the removal victim — highest ordinal)
    // holds an admitted, unanswered ticket when the removal starts.
    let (s0, gate0) = gated_shard("gated_net", 0, 4);
    let (s1, gate1) = gated_shard("gated_net", 1, 4);
    let fleet = std::sync::Arc::new(ShardedService::from_shards(vec![s0, s1]).unwrap());

    // Land one ticket on replica 1 specifically (direct shard handle), then
    // release the handle so the drain can join deterministically.
    let ticket = {
        let shards = fleet.shards();
        let t = shards[1].try_submit(vec![7]).unwrap();
        assert_eq!(shards[1].outstanding(), 1);
        t
    };

    // Removal must BLOCK until the wedged worker drains — assert it has not
    // returned, then release the gate and watch it complete.
    let (done_tx, done_rx) = mpsc::channel();
    let fleet2 = std::sync::Arc::clone(&fleet);
    let remover = std::thread::spawn(move || {
        let removed = fleet2.remove_shard("gated_net").unwrap();
        done_tx.send(removed).unwrap();
    });
    assert!(
        done_rx.recv_timeout(Duration::from_millis(100)).is_err(),
        "removal returned while the victim still held an in-flight ticket"
    );
    gate1.send(()).unwrap();
    assert_eq!(done_rx.recv_timeout(Duration::from_secs(10)).unwrap(), 1);
    remover.join().unwrap();

    // THE guarantee: the ticket admitted before the removal was answered,
    // not dropped.
    assert_eq!(ticket.wait().unwrap(), vec![0, 0, 0]);

    // The survivor still serves (replica 0, gated: release then submit).
    assert_eq!(fleet.replica_count("gated_net"), 1);
    gate0.send(()).unwrap();
    assert_eq!(fleet.try_infer("gated_net", vec![1]).unwrap(), vec![0, 0, 0]);
    drop((gate0, gate1));
    match std::sync::Arc::try_unwrap(fleet) {
        Ok(f) => f.shutdown(),
        Err(_) => panic!("fleet handle leaked"),
    }
}

#[test]
fn spike_scales_up_within_predicted_budget_and_idle_scales_down() {
    // The plan prices tiny_q8 replicas from the fitted models on a ZCU104.
    let registry = small_registry();
    let platform = Platform::zcu104();
    let demands = [NetworkDemand::new(zoo::tiny())];
    let plan = plan_fleet(&demands, &registry, &platform, 0.8).unwrap();
    let budget = plan.capped_budget();
    assert!(plan.replicas_for("tiny_q8") >= 2, "platform fits several replicas");

    // Live fleet: ONE gated replica of tiny_q8, cap 1 — so the spike's
    // rejection count is exact (the wedged worker cannot drain anything).
    let (shard, gate) = gated_shard("tiny_q8", 0, 1);
    let fleet = ShardedService::from_shards(vec![shard]).unwrap();
    let policy = SloPolicy { window: 1, ..SloPolicy::default() };
    let template = ShardSpec::golden("tiny_q8").with_batch_size(4);
    let mut scaler = Autoscaler::new(plan, policy, vec![template]);

    // Deterministic spike: 1 admission fills the cap, 3 attempts bounce.
    let ticket = fleet.try_submit("tiny_q8", vec![1; 64]).unwrap();
    for _ in 0..3 {
        assert!(matches!(
            fleet.try_submit("tiny_q8", vec![2; 64]),
            Err(Error::Overloaded(_))
        ));
    }
    // Unwedge so the stats snapshot is immediate (the rejection counter is
    // caller-side and already final at 3).
    gate.send(()).unwrap();
    assert_eq!(ticket.wait().unwrap(), vec![0, 0, 0]);

    // Round 1: overload → exactly one scale-up, justified by the models.
    let decisions = scaler.step(&fleet).unwrap();
    assert_eq!(decisions.len(), 1, "{decisions:?}");
    let d = &decisions[0];
    assert_eq!(d.action, ScaleAction::Up);
    assert_eq!((d.from_replicas, d.to_replicas), (1, 2));
    assert!(d.unit.llut > 0, "unit cost comes from the registry");
    assert!(
        d.predicted_total.fits_within(&budget),
        "scale-up must stay inside the predicted budget: {} vs {budget}",
        d.predicted_total
    );
    assert!(d.to_string().contains("scale-up tiny_q8 1→2"), "{d}");
    assert_eq!(fleet.replica_count("tiny_q8"), 2, "decision was applied live");

    // The new (golden) replica actually serves — bit-exact against the
    // golden model — while replica 0 sits wedged at load 0-vs-0 tie... the
    // router prefers index 0 only on ties, and replica 0 has load 0 now, so
    // pin correctness through several requests that round-robin by load.
    let tiny = zoo::tiny();
    let golden = GoldenCnn::new(tiny.clone(), BlockKind::Conv2).unwrap();
    let img = tiny.synthetic_images_i32(1, 42).pop().unwrap();
    let want: Vec<i32> = golden
        .infer(&img.iter().map(|&v| v as i64).collect::<Vec<_>>())
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect();
    // Occupy replica 0 (gated, wedged) with one uncapped submit so every
    // bounded admission below deterministically routes to the golden one.
    let parked = fleet.submit("tiny_q8", img.clone()).unwrap();
    for _ in 0..3 {
        assert_eq!(fleet.try_infer("tiny_q8", img.clone()).unwrap(), want);
    }

    // Round 2: one calm window → idle → drain-based scale-down back to the
    // floor. Highest ordinal (the golden replica) is the victim.
    let decisions = scaler.step(&fleet).unwrap();
    assert_eq!(decisions.len(), 1, "{decisions:?}");
    assert_eq!(decisions[0].action, ScaleAction::Down);
    assert_eq!(
        (decisions[0].from_replicas, decisions[0].to_replicas),
        (2, 1)
    );
    assert_eq!(fleet.replica_count("tiny_q8"), 1);

    // Round 3: no further decisions — the survivor reads Healthy (the
    // parked request fills its whole 1-slot queue, so it is not "idle"),
    // and even a calm verdict could not shrink below the plan's floor.
    let decisions = scaler.step(&fleet).unwrap();
    assert!(decisions.is_empty(), "{decisions:?}");

    // The parked ticket on the surviving gated replica was never lost.
    gate.send(()).unwrap();
    assert_eq!(parked.wait().unwrap(), vec![0, 0, 0]);
    drop(gate);
    fleet.shutdown();
}
