//! Integration: Algorithm 1 over the full campaign — the paper's Table 4
//! acceptance criteria, on our data.

use convkit::blocks::{BlockKind, ConvBlockConfig};
use convkit::coordinator::dse::DseEngine;
use convkit::models::ResourceModel;
use convkit::synth::Resource;

fn report() -> convkit::coordinator::dse::DseReport {
    DseEngine::new().run().unwrap()
}

#[test]
fn every_registered_block_gets_five_models() {
    let rep = report();
    assert_eq!(rep.registry.len(), BlockKind::ALL.len() * 5);
}

#[test]
fn table4_acceptance_all_llut_models_clear_bar() {
    // Paper Table 4: R² ≥ 0.94 on every block's LLUT model, MAPE ≤ ~3%.
    let rep = report();
    for b in BlockKind::ALL {
        let e = rep.registry.get(b, Resource::Llut).unwrap();
        assert!(e.metrics.r2 >= 0.9, "{b}: R² {}", e.metrics.r2);
        assert!(e.metrics.mape <= 6.0, "{b}: MAPE {}", e.metrics.mape);
    }
}

#[test]
fn conv3_llut_model_is_segmented_and_exact() {
    // Paper Table 4's most distinctive row: Conv3 R² = 1.00, EAMP = 0.00.
    let rep = report();
    let e = rep.registry.get(BlockKind::Conv3, Resource::Llut).unwrap();
    match &e.model {
        ResourceModel::Segmented { var, model } => {
            assert_eq!(*var, 'c');
            assert!((model.r2 - 1.0).abs() < 1e-9, "R² {}", model.r2);
        }
        other => panic!("expected segmented Conv3 LLUT model, got {other}"),
    }
    assert_eq!(e.metrics.mape, 0.0, "EAMP must be exactly 0");
    assert!((e.metrics.r2 - 1.0).abs() < 1e-9);
}

#[test]
fn conv4_closed_form_matches_paper_shape() {
    // Paper: LLUTs = 20.886 + 1.004·d + 1.037·c (R² = 0.989). Ours must be a
    // degree-1 polynomial with intercept ~10-30 and both slopes ~0.4-1.6.
    let rep = report();
    let e = rep.registry.get(BlockKind::Conv4, Resource::Llut).unwrap();
    match &e.model {
        ResourceModel::Poly(p) => {
            assert_eq!(p.degree, 1, "{p}");
            let at = |dx: u32, cx: u32| {
                p.terms.iter().find(|t| t.dx == dx && t.cx == cx).map(|t| t.coef).unwrap_or(0.0)
            };
            let intercept = at(0, 0);
            let d_slope = at(1, 0);
            let c_slope = at(0, 1);
            assert!((10.0..=30.0).contains(&intercept), "intercept {intercept}");
            assert!((0.4..=1.6).contains(&d_slope), "d slope {d_slope}");
            assert!((0.4..=1.6).contains(&c_slope), "c slope {c_slope}");
        }
        other => panic!("expected polynomial, got {other}"),
    }
}

#[test]
fn conv1_model_captures_the_curved_surface() {
    // Figure 1 shows a curved (d·c) surface: the selected model needs degree
    // ≥ 2 and R² ≈ 0.997 (paper Table 4).
    let rep = report();
    let e = rep.registry.get(BlockKind::Conv1, Resource::Llut).unwrap();
    match &e.model {
        ResourceModel::Poly(p) => {
            assert!(p.degree >= 2, "{p}");
            assert!(p.r2 >= 0.98, "R² {}", p.r2);
        }
        other => panic!("expected polynomial, got {other}"),
    }
}

#[test]
fn dsp_models_are_exact_constants() {
    let rep = report();
    for b in BlockKind::ALL {
        let e = rep.registry.get(b, Resource::Dsp).unwrap();
        assert!((e.metrics.r2 - 1.0).abs() < 1e-9, "{b}");
        assert_eq!(e.metrics.mape, 0.0, "{b}");
        for (d, c) in [(3, 3), (8, 11), (16, 16)] {
            let cfg = ConvBlockConfig::new(b, d, c).unwrap();
            assert_eq!(rep.registry.predict(&cfg).unwrap().dsp, b.dsp_count(), "{cfg}");
        }
    }
}

#[test]
fn interpolation_error_within_jitter_band() {
    // Predictions at grid points must sit within a few percent of the
    // measured values — the models are the measurement minus noise.
    let rep = report();
    let mut worst: f64 = 0.0;
    for b in BlockKind::ALL {
        for (d, c) in [(4, 12), (9, 9), (15, 5)] {
            let cfg = ConvBlockConfig::new(b, d, c).unwrap();
            let pred = rep.registry.predict(&cfg).unwrap();
            let meas = rep.dataset.get(b, d, c).unwrap().res;
            let rel = (pred.llut as f64 - meas.llut as f64).abs() / meas.llut.max(1) as f64;
            worst = worst.max(rel);
        }
    }
    assert!(worst < 0.12, "worst LLUT interpolation error {worst}");
}

#[test]
fn models_predict_held_out_half_grid() {
    // Fit on even data-widths only, predict the odd ones: generalization, not
    // memorization. (The paper validates in-sample; this is stronger.)
    use convkit::models::{ModelRegistry, SelectOptions};
    use convkit::synthdata::Dataset;
    let rep = report();
    let train = Dataset {
        records: rep
            .dataset
            .records
            .iter()
            .filter(|r| r.data_bits % 2 == 0)
            .copied()
            .collect(),
    };
    let reg = ModelRegistry::fit(&train, &SelectOptions::default()).unwrap();
    for b in [BlockKind::Conv2, BlockKind::Conv4] {
        for d in [5u32, 9, 13] {
            let cfg = ConvBlockConfig::new(b, d, 8).unwrap();
            let pred = reg.predict(&cfg).unwrap().llut as f64;
            let meas = rep.dataset.get(b, d, 8).unwrap().res.llut as f64;
            let rel = (pred - meas).abs() / meas.max(1.0);
            assert!(rel < 0.15, "{b} d={d}: held-out error {rel}");
        }
    }
}
