//! Integration tests for the telemetry plane (`obs`), pinning the three
//! contracts the module docs promise:
//!
//! 1. **Sim/live parity** — a live fleet serving N sequential requests and a
//!    simulated fleet offered the same N arrivals emit *identical* per-kind
//!    span counts through the one shared `obs::Sink` interface.
//! 2. **Overflow accounting** — when a gated executor wedges the worker and
//!    the span ring fills, the drop counter accounts for every span the ring
//!    refused (recorded + dropped == emitted) while admission and completion
//!    accounting stay exact. Referenced by name from `docs/HOTPATH.md` §9.
//! 3. **Percentile parity** — the log-linear histogram's p95 brackets the
//!    exact nearest-rank p95 computed from a `LatencyRing` window over the
//!    same samples, within the histogram's 1/32 relative bucket width (and
//!    exactly, in the linear sub-32 range).

use convkit::cnn::zoo;
use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{CoalescePolicy, Shard, ShardSpec, ShardedService};
use convkit::obs::{LogLinearHistogram, SpanKind, Telemetry};
use convkit::simulate::{Admission, SimFleet, SimServiceModel};
use convkit::util::error::Result;
use convkit::util::stats::{percentile_nearest_rank, LatencyRing};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Requests driven through both fleets in the parity test.
const PARITY_REQUESTS: usize = 24;

/// With one replica and a strictly sequential blocking client, every request
/// is its own batch on the live side; spacing simulated arrivals far wider
/// than the modeled service time reproduces that one-request-per-batch
/// timeline on the virtual clock. Every span kind must then count exactly
/// N on BOTH planes: enqueue/route/guard_release once per request,
/// window_open/window_close/batch_start/batch_end once per batch (= N).
#[test]
fn live_and_sim_fleets_emit_identical_span_kind_counts() {
    let n = PARITY_REQUESTS;

    // Live: one golden-backed replica, observed end to end.
    let live = Arc::new(Telemetry::new());
    let fleet = ShardedService::start_observed(
        &[ShardSpec::golden("tiny_q8").with_batch_size(8)],
        Arc::clone(&live),
    )
    .expect("observed fleet start");
    let imgs: Vec<Arc<[i32]>> =
        zoo::tiny().synthetic_images_i32(4, 0xB0).into_iter().map(Into::into).collect();
    for k in 0..n {
        fleet
            .infer("tiny_q8", Arc::clone(&imgs[k % imgs.len()]))
            .expect("live inference");
    }
    fleet.shutdown();

    // Sim: the same shape on the virtual clock, through the same Sink.
    let sim = Arc::new(Telemetry::new());
    let mut sf = SimFleet::new(&[SimServiceModel::new("tiny_q8", 0.01, 8, 1)])
        .expect("sim fleet");
    sf.set_sink(Arc::clone(&sim));
    for k in 0..n {
        // 1 ms apart vs a 0.01 ms service time: each request completes long
        // before the next arrives, exactly like the blocking live client.
        let adm = sf.offer("tiny_q8", (k as u64 + 1) * 1_000_000).expect("offer");
        assert!(matches!(adm, Admission::Admitted { .. }), "arrival {k} rejected");
    }
    sf.drain();

    let live_counts = live.span_kind_counts();
    let sim_counts = sim.span_kind_counts();
    assert_eq!(
        live_counts, sim_counts,
        "live and simulated per-kind span timelines diverged"
    );
    for kind in SpanKind::ALL {
        assert_eq!(
            live_counts[kind.name()],
            n as u64,
            "span kind `{}` should fire once per request on both planes",
            kind.name()
        );
    }
    assert_eq!(live.spans_dropped(), 0, "default ring never fills at N={n}");
    assert_eq!(sim.spans_dropped(), 0, "hub ring never fills at N={n}");
}

/// An executor that refuses to run a batch until the test releases it — the
/// worker wedges inside `infer_batch` while admissions (and their spans)
/// pile up against a deliberately tiny span ring.
struct GatedExecutor {
    gate: mpsc::Receiver<()>,
}

impl BatchExecutor for GatedExecutor {
    fn infer_batch(&mut self, images: &[Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        // A closed gate (test ended early) just lets the batch through —
        // the accounting assertions have already run by then.
        let _ = self.gate.recv();
        Ok(images.iter().map(|im| vec![im.len() as i32]).collect())
    }

    fn label(&self) -> String {
        "gated".to_string()
    }
}

/// `docs/HOTPATH.md` §9 cites this test by name: the ring drops NEW spans
/// when full (never overwriting committed ones) and the drop counter
/// accounts for every one of them — recorded + dropped equals the exact
/// number of emission points the request walk executed, and the drops cost
/// the serving plane nothing (every request still admitted and answered).
#[test]
fn span_ring_overflow_accounts_for_every_drop() {
    const CAPACITY: usize = 4;
    const REQUESTS: u64 = 8;

    let obs = Arc::new(Telemetry::with_span_capacity(CAPACITY));
    let scope = obs.scope_for("gated", 0);
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let service = InferenceService::start_factory_observed(
        move || Ok(GatedExecutor { gate: gate_rx }),
        4,
        CoalescePolicy::fixed(Duration::from_micros(100)),
        Some(scope.clone()),
    );
    // Worker and admission path share one ring, as `Shard::start` wires it.
    let shard = Shard::from_service("gated", 0, 16, service).observed(scope);

    let img: Arc<[i32]> = vec![1, 2, 3].into();
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|_| shard.submit(Arc::clone(&img)).expect("uncapped admission"))
        .collect();
    // More gate tokens than batches can possibly form (batching is
    // nondeterministic under a wedged worker; the accounting below reads the
    // exact batch count back from the service stats).
    for _ in 0..REQUESTS {
        gate_tx.send(()).expect("worker alive");
    }
    for t in tickets {
        t.wait().expect("request served despite span drops");
    }

    let stats = shard.stats();
    let batches = stats.service.batches;
    assert!(
        (1..=REQUESTS).contains(&batches),
        "{REQUESTS} requests must coalesce into 1..={REQUESTS} batches, got {batches}"
    );
    // Emission points per the request walk: route + enqueue at admission and
    // guard_release at completion (3 per request); window_open, window_close,
    // batch_start, batch_end once per batch.
    let emitted = 3 * REQUESTS + 4 * batches;
    assert_eq!(
        obs.spans_recorded(),
        CAPACITY as u64,
        "an undrained ring commits exactly its capacity"
    );
    assert_eq!(
        obs.spans_recorded() + obs.spans_dropped(),
        emitted,
        "drop counter must account for every span the ring refused"
    );
    // Dropped spans are lost telemetry, never lost requests.
    assert_eq!(stats.service.requests, REQUESTS, "every admitted request answered");
    assert_eq!(stats.service.errors, 0);
    assert_eq!(stats.rejected, 0, "uncapped submits reject nothing");
    shard.shutdown();
}

/// Deterministic 64-bit sample stream (splitmix-style) so the test never
/// depends on wall-clock latencies.
fn sample_stream(count: usize, range: u64) -> Vec<u64> {
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    (0..count)
        .map(|_| {
            state = state
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            (state >> 33) % range + 1
        })
        .collect()
}

/// The unified registry's log-linear histogram subsumes the serving layer's
/// `LatencyRing` nearest-rank p95: over identical samples the ring's exact
/// nearest-rank answer always lies inside the histogram's p95 bucket, whose
/// relative width is at most 1/32 — and in the linear sub-32 range the two
/// agree exactly.
#[test]
fn histogram_p95_brackets_the_latency_ring_nearest_rank_p95() {
    let samples = sample_stream(2_000, 1_000_000);
    let hist = LogLinearHistogram::new();
    let ring = LatencyRing::new(4_096);
    for &v in &samples {
        hist.record(v);
        ring.record(v);
    }

    // Window wider than the stream: the ring retains every sample, so its
    // snapshot IS the exact population the histogram saw.
    let mut window = ring.snapshot();
    assert_eq!(window.len(), samples.len(), "no eviction at this window size");
    window.sort_unstable();
    let exact = percentile_nearest_rank(&window, 95);

    let (lo, hi) = hist.percentile_bounds(95);
    assert!(
        (lo..=hi).contains(&exact),
        "nearest-rank p95 {exact} outside histogram bucket [{lo}, {hi}]"
    );
    assert!(hist.percentile(95) >= exact, "reported p95 never under-reports");
    assert!(
        hi - lo <= lo / 32 + 1,
        "bucket [{lo}, {hi}] wider than the promised 1/32 relative resolution"
    );

    // Linear range: one bucket per value, so parity is exact.
    let small_hist = LogLinearHistogram::new();
    let small_ring = LatencyRing::new(64);
    let mut small: Vec<u64> = sample_stream(50, 31);
    for &v in &small {
        small_hist.record(v);
        small_ring.record(v);
    }
    let mut small_window = small_ring.snapshot();
    small_window.sort_unstable();
    small.sort_unstable();
    assert_eq!(small_window, small, "ring snapshot is the exact population");
    for pct in [50, 95, 99, 100] {
        assert_eq!(
            small_hist.percentile(pct),
            percentile_nearest_rank(&small_window, pct),
            "p{pct} must match exactly in the sub-32 linear range"
        );
    }
}
