//! Integration tests for the SLO policy search:
//!
//! * **Determinism** — the same seed + scenario + grid produces a
//!   byte-identical Pareto JSON across runs (the property that lets CI
//!   archive and diff `POLICY_pareto.json`), and different seeds diverge.
//! * **Front consistency** — the reported front is non-empty, its flags
//!   match `front()`, no front row is dominated, and every non-front row
//!   is dominated by some front row.
//! * **Structure** — rows ride in deterministic grid order with the swept
//!   knobs, and the text rendering names the essentials.

use convkit::cnn::zoo;
use convkit::coordinator::dse::DseEngine;
use convkit::coordinator::jobs::JobPool;
use convkit::fleetplan::NetworkDemand;
use convkit::models::{ModelRegistry, SelectOptions};
use convkit::platform::Platform;
use convkit::simulate::{
    policysearch, PolicyGrid, PolicyScore, Scenario, ScenarioShape, WhatIfOptions,
};
use convkit::synthdata::SweepOptions;

fn registry() -> ModelRegistry {
    let eng = DseEngine {
        sweep: SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() },
        select: SelectOptions::default(),
        pool: JobPool::with_workers(2),
        cache: None,
    };
    eng.run().unwrap().registry
}

fn test_grid() -> PolicyGrid {
    PolicyGrid {
        overload_targets: vec![0.005, 0.05],
        p95_ratios: vec![2.0, 8.0],
        idle_queue_utils: vec![0.05],
        windows: vec![2],
    }
}

fn test_options() -> WhatIfOptions {
    WhatIfOptions {
        // Small + fast: every grid row replays the trace once.
        min_arrivals: 3_000,
        control_interval_ms: 0.25,
        ..WhatIfOptions::default()
    }
}

#[test]
fn policysearch_json_is_byte_identical_per_seed_and_differs_across_seeds() {
    let reg = registry();
    let demands =
        [NetworkDemand::new(zoo::tiny()), NetworkDemand::new(zoo::slim_q6())];
    let platforms = Platform::all();
    let (grid, opts) = (test_grid(), test_options());
    let run = |seed: u64| {
        let scenario = Scenario::new(ScenarioShape::Burst, Vec::new(), 0.0, 0.0, seed);
        policysearch::search(&demands, &reg, &platforms, &scenario, &grid, &opts)
            .unwrap()
            .to_json()
    };
    let mut per_seed = Vec::new();
    for seed in [42u64, 43] {
        let a = run(seed);
        let b = run(seed);
        assert_eq!(a, b, "seed {seed}: Pareto JSON must be byte-identical across runs");
        per_seed.push(a);
    }
    assert_ne!(per_seed[0], per_seed[1], "different seeds must diverge");
}

#[test]
fn pareto_front_is_nonempty_consistent_and_dominance_correct() {
    let reg = registry();
    let demands =
        [NetworkDemand::new(zoo::tiny()), NetworkDemand::new(zoo::slim_q6())];
    let scenario = Scenario::new(ScenarioShape::Burst, Vec::new(), 0.0, 0.0, 42);
    let report = policysearch::search(
        &demands,
        &reg,
        &Platform::all(),
        &scenario,
        &test_grid(),
        &test_options(),
    )
    .unwrap();

    assert_eq!(report.rows.len(), test_grid().len(), "one scored row per grid point");
    assert!(report.arrivals >= 3_000);
    for r in &report.rows {
        assert!(r.sustained_qps > 0.0, "{r:?}");
        assert!(r.p95_ms > 0.0, "{r:?}");
        assert!(r.replica_seconds > 0.0, "{r:?}");
        assert!((0.0..=1.0).contains(&r.reject_rate), "{r:?}");
    }

    let objectives = |r: &PolicyScore| {
        [-r.sustained_qps, r.p95_ms, r.reject_rate, r.replica_seconds]
    };
    let dominates = |a: &PolicyScore, b: &PolicyScore| {
        let (oa, ob) = (objectives(a), objectives(b));
        oa.iter().zip(&ob).all(|(x, y)| x <= y) && oa.iter().zip(&ob).any(|(x, y)| x < y)
    };
    let front = report.front();
    assert!(!front.is_empty(), "a finite sweep always has a non-dominated row");
    assert_eq!(
        front.len(),
        report.rows.iter().filter(|r| r.pareto).count(),
        "front() mirrors the pareto flags"
    );
    for &f in &front {
        assert!(
            !report.rows.iter().any(|other| dominates(other, f)),
            "front row is dominated: {f:?}"
        );
    }
    for r in report.rows.iter().filter(|r| !r.pareto) {
        assert!(
            report.rows.iter().any(|other| dominates(other, r)),
            "non-front row must be dominated by someone: {r:?}"
        );
    }

    // Rows ride in grid order with the swept knobs attached.
    let expected = test_grid().policies(&test_options().policy);
    for (row, want) in report.rows.iter().zip(&expected) {
        assert_eq!(row.policy.overload_target, want.overload_target);
        assert_eq!(row.policy.p95_ratio, want.p95_ratio);
        assert_eq!(row.policy.idle_queue_util, want.idle_queue_util);
        assert_eq!(row.policy.window, want.window);
    }

    // The text rendering names the essentials.
    let text = convkit::report::pareto_table(&report);
    assert!(text.contains("SLO policy search"), "{text}");
    assert!(text.contains("Pareto front:"), "{text}");
    assert!(text.contains(&report.platform), "{text}");
}
