//! Heterogeneous-pool integration: the N-device fleet plane end to end.
//!
//! * **Planning at scale** — `plan_pool` packs the VGG-16-scale zoo spec
//!   plus two small networks across a mixed KV260 + ZCU104 + ZCU111 pool:
//!   every network lands somewhere, every used device respects its own
//!   threshold budget, and the JSON plan is deterministic.
//! * **Device loss mid-trace** — `SimFleet::fail_device` tears a whole
//!   contention group out of routing while every admitted request still
//!   completes (the live drain semantics on the virtual clock), and
//!   `rebind_device` replans the work onto a spare after the outage.
//! * **Amortized rebind** — the same `Autoscaler::step_target` path that
//!   drives live fleets emits a justified `ScaleAction::Rebind` when the
//!   primary platform is exhausted and the reconfiguration outage pays
//!   back, then refuses to thrash on the next round.

use convkit::cnn::zoo;
use convkit::coordinator::dse::DseEngine;
use convkit::coordinator::jobs::JobPool;
use convkit::coordinator::ShardSpec;
use convkit::fleetplan::{
    plan_pool, Autoscaler, DevicePool, FleetPlan, NetworkDemand, NetworkPlan, PoolDevice,
    ReconfigPolicy, ScaleAction, SloPolicy,
};
use convkit::models::{ModelRegistry, SelectOptions};
use convkit::platform::Platform;
use convkit::simulate::{Admission, SimFleet, SimServiceModel};
use convkit::synth::ResourceVector;
use convkit::synthdata::SweepOptions;

fn registry() -> ModelRegistry {
    let eng = DseEngine {
        sweep: SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() },
        select: SelectOptions::default(),
        pool: JobPool::with_workers(2),
        cache: None,
    };
    eng.run().unwrap().registry
}

#[test]
fn a_mixed_three_device_pool_plans_the_vgg16_scale_spec() {
    let reg = registry();
    let demands = vec![
        NetworkDemand::new(zoo::vgg16_q8()),
        NetworkDemand::new(zoo::lenet_ish()),
        NetworkDemand::new(zoo::tiny()),
    ];
    let pool = DevicePool::parse("kv260,zcu104,zcu111", 0.8).unwrap();
    let plan = plan_pool(&demands, &reg, &pool).unwrap();

    // Every demanded network is placed somewhere in the pool.
    for name in ["vgg16_q8", "lenet_q8", "tiny_q8"] {
        assert!(plan.replicas_for(name) >= 1, "{name} was not placed on any device");
    }

    // Each used device's sub-fleet respects that device's own threshold
    // budget — the invariant the per-device max-min fill solves under.
    assert_eq!(plan.devices.len(), pool.devices.len());
    let mut used = 0;
    for (dp, dev) in plan.devices.iter().zip(&pool.devices) {
        assert_eq!(dp.device, dev.name);
        if dp.plan.networks.is_empty() {
            continue;
        }
        used += 1;
        assert!(
            dp.plan.total.fits_within(&dev.budget()),
            "{}: solved total {:?} exceeds the device budget",
            dp.device,
            dp.plan.total,
        );
    }
    assert!(used >= 1, "the pool plan used no device at all");

    // Same inputs, same bytes: the plan JSON is the CI-archived artifact.
    let json = plan.to_json();
    assert_eq!(json, plan_pool(&demands, &reg, &pool).unwrap().to_json());
    assert!(json.contains("\"pool\""));
    assert!(json.contains("\"vgg16_q8\""));

    // The operator rendering names every device, used or not.
    let table = convkit::report::pool_table(&plan);
    for dp in &plan.devices {
        assert!(table.contains(&dp.device), "pool table misses {}", dp.device);
    }
}

#[test]
fn killing_a_device_mid_trace_drops_nothing_and_the_pool_replans() {
    let model = SimServiceModel::new("svc", 5.0, 2, 2).on_platform("ZCU104", 0.4);
    let mut fleet = SimFleet::new(&[model]).unwrap();

    // Fill both replicas to their cap at t=0: 4 admitted, in flight/queued.
    let mut admitted = 0u64;
    for _ in 0..4 {
        if matches!(fleet.offer("svc", 0).unwrap(), Admission::Admitted { .. }) {
            admitted += 1;
        }
    }
    assert_eq!(admitted, 4);

    // The device dies mid-trace: both replicas leave routing immediately,
    // but their admitted backlog keeps draining — nothing is dropped.
    fleet.run_until(1_000_000); // 1 ms: batches are in service
    assert_eq!(fleet.fail_device("ZCU104"), 2);
    assert_eq!(fleet.replica_count("svc"), 0);

    // The pool replans: a spare device is reprogrammed with the same
    // bitstream and pays a 10 ms outage before its replicas activate.
    assert_eq!(fleet.rebind_device("ZCU111", "svc", 2, 10.0).unwrap(), 0);

    // During the outage there is nothing routable: offers bounce (bounded
    // admission), they do not error and they do not strand anything.
    assert!(matches!(fleet.offer("svc", 5_000_000).unwrap(), Admission::Rejected));

    // Past the outage the replacement replicas serve new load.
    fleet.run_until(30_000_000);
    assert_eq!(fleet.replica_count("svc"), 2);
    for _ in 0..4 {
        if matches!(fleet.offer("svc", 30_000_000).unwrap(), Admission::Admitted { .. }) {
            admitted += 1;
        }
    }
    assert_eq!(admitted, 8);
    fleet.drain();

    let stats = fleet.network_stats();
    assert_eq!(stats.len(), 1);
    let s = &stats[0];
    assert_eq!(s.offered, 9);
    assert_eq!(s.rejected, 1);
    assert_eq!(s.admitted, 8);
    assert_eq!(
        s.completed, s.admitted,
        "an admitted request was dropped across the device loss"
    );
}

/// Hand-built plan: one network priced at 700 DSP per replica on a ZCU104,
/// so a second replica cannot fit under the 80% cap (2×700 > 1382) and the
/// only way out is a pool rebind.
fn exhausted_plan() -> FleetPlan {
    let platform = Platform::zcu104();
    let unit = ResourceVector::new(100, 0, 200, 0, 700);
    FleetPlan {
        platform: platform.clone(),
        cap: 0.8,
        networks: vec![NetworkPlan {
            network: "hot".into(),
            unit,
            predicted_ms: 1.0,
            fill_ms: 0.0,
            util_frac: 700.0 / 1382.0,
            replicas: 1,
            min_replicas: 1,
            max_replicas: 0,
            weight: 1.0,
        }],
        total: unit,
        utilization: platform.utilization(&unit),
    }
}

#[test]
fn an_exhausted_platform_rebinds_a_spare_device_once_the_outage_amortizes() {
    // Virtual fleet: one replica on the primary, overloaded 60% (4 of 10
    // offered requests admitted at its cap of 4).
    let model = SimServiceModel::new("hot", 1.0, 4, 1).on_platform("ZCU104", 0.5);
    let mut fleet = SimFleet::new(&[model]).unwrap();
    for _ in 0..10 {
        let _ = fleet.offer("hot", 0).unwrap();
    }
    // Let the admitted backlog complete so the window holds both sides of
    // the overload ratio (completions AND rejections).
    fleet.run_until(10_000_000);

    // Controller over the exhausted plan, pool-attached: the ZCU104 is the
    // primary (never a rebind target), the ZCU111 is an idle spare. A 50 ms
    // outage against a 4-replica gain amortizes in well under the limit.
    let pool = DevicePool::new(vec![
        PoolDevice::new(Platform::zcu104(), 0.8),
        PoolDevice::new(Platform::zcu111(), 0.8),
    ])
    .unwrap();
    let reconfig = ReconfigPolicy { downtime_s: 0.05, payback_limit_s: 20.0 };
    let mut scaler = Autoscaler::new(
        exhausted_plan(),
        SloPolicy { window: 1, ..SloPolicy::default() },
        vec![ShardSpec::golden("hot").with_queue_cap(4)],
    )
    .with_pool(pool, reconfig);

    let decisions = scaler.step_target(&mut fleet).unwrap();
    assert_eq!(decisions.len(), 1);
    let d = &decisions[0];
    assert_eq!(d.action, ScaleAction::Rebind);
    assert_eq!(d.device.as_deref(), Some("ZCU111"));
    assert_eq!((d.from_replicas, d.to_replicas), (1, 5));
    assert!((d.at_ms - 10.0).abs() < 1e-9, "stamped at virtual now, got {}", d.at_ms);
    assert!(d.reason.contains("amortizing"), "unjustified rebind: {}", d.reason);
    assert!(d.reason.contains("ZCU111"), "reason names no device: {}", d.reason);

    // The rebind is physical on the virtual clock: 4 fresh replicas come up
    // only after the 50 ms reprogramming outage.
    fleet.run_until(30_000_000);
    assert_eq!(fleet.replica_count("hot"), 1, "replicas appeared during the outage");
    fleet.run_until(70_000_000);
    assert_eq!(fleet.replica_count("hot"), 5);

    // A bigger burst overloads even the widened fleet (capacity 5×4 = 20
    // outstanding), so the next control round sees Overloaded again…
    let mut admitted = 0;
    for _ in 0..30 {
        if matches!(fleet.offer("hot", 70_000_000).unwrap(), Admission::Admitted { .. }) {
            admitted += 1;
        }
    }
    assert_eq!(admitted, 20);
    fleet.run_until(150_000_000);

    // …but the primary is still exhausted and the spare already holds this
    // bitstream: the thrash guard suppresses a second rebind — no decision.
    assert!(scaler.step_target(&mut fleet).unwrap().is_empty());

    fleet.drain();
    let s = &fleet.network_stats()[0];
    assert_eq!(s.offered, 40);
    assert_eq!(s.rejected, 16);
    assert_eq!(s.completed, s.admitted, "a rebind dropped admitted work");
}
