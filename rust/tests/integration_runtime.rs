//! End-to-end runtime integration: the PJRT-executed AOT artifacts must be
//! **bit-exact** against the block-level golden model — the verification that
//! all three layers (Pallas kernel → JAX model → rust coordinator) compute
//! the same function.
//!
//! These tests are gated on `artifacts/` existing (run `make artifacts`
//! first); without it they pass vacuously with a notice, so plain
//! `cargo test` works on a fresh checkout.

use convkit::blocks::BlockKind;
use convkit::cnn::{zoo, GoldenCnn};
use convkit::coordinator::service::{InferenceService, PjrtExecutor};
use convkit::fixedpoint::QFormat;
use convkit::runtime::{artifacts_dir, Runtime};
use convkit::util::rng::SplitMix64;

fn artifacts_ready() -> bool {
    if !convkit::runtime::runtime_available() {
        eprintln!("NOTE: built without the `pjrt` feature; skipping runtime test");
        return false;
    }
    let ok = artifacts_dir().join("lenet_q8.hlo.txt").exists();
    if !ok {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping runtime test");
    }
    ok
}

fn random_images(spec: &convkit::cnn::NetworkSpec, n: usize, seed: u64) -> Vec<Vec<i64>> {
    let q = QFormat::new(spec.layers[0].data_bits).unwrap();
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            (0..spec.in_ch * spec.in_h * spec.in_w)
                .map(|_| rng.range_i64(q.min(), q.max()))
                .collect()
        })
        .collect()
}

fn check_network_bit_exact(name: &str) {
    if !artifacts_ready() {
        return;
    }
    let spec = zoo::all().into_iter().find(|n| n.name == name).expect("zoo entry");
    let golden = GoldenCnn::new(spec.clone(), BlockKind::Conv2).unwrap();
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_named(&artifacts_dir(), name).unwrap();
    let batch: usize = art.meta.dims("input_shape").unwrap()[0];
    let images = random_images(&spec, batch, 0xE2E0 + name.len() as u64);
    // PJRT path.
    let flat: Vec<i32> = images.iter().flatten().map(|&v| v as i32).collect();
    let dims = vec![batch, spec.in_ch, spec.in_h, spec.in_w];
    let out = art.run_i32(&[(&flat, &dims)]).unwrap();
    let logits = &out[0];
    assert_eq!(logits.len(), batch * spec.classes());
    // Golden path.
    for (i, img) in images.iter().enumerate() {
        let want = golden.infer(img).unwrap();
        let got: Vec<i64> = logits[i * spec.classes()..(i + 1) * spec.classes()]
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert_eq!(got, want, "{name}: image {i} diverges between PJRT and golden");
    }
}

#[test]
fn lenet_q8_pjrt_matches_golden_bit_exact() {
    check_network_bit_exact("lenet_q8");
}

#[test]
fn tiny_q8_pjrt_matches_golden_bit_exact() {
    check_network_bit_exact("tiny_q8");
}

#[test]
fn slim_q6_pjrt_matches_golden_bit_exact() {
    check_network_bit_exact("slim_q6");
}

#[test]
fn kernel_artifact_matches_fixedpoint_reference() {
    if !artifacts_ready() {
        return;
    }
    use convkit::fixedpoint::{conv3x3_plane_ref, Rounding};
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_named(&artifacts_dir(), "conv3x3_q8").unwrap();
    let (h, w) = (16usize, 16usize);
    let q8 = QFormat::new(8).unwrap();
    let mut rng = SplitMix64::new(777);
    let plane: Vec<i64> = (0..h * w).map(|_| rng.range_i64(q8.min(), q8.max())).collect();
    let coeffs: [i64; 9] = std::array::from_fn(|_| rng.range_i64(q8.min(), q8.max()));
    let plane_i32: Vec<i32> = plane.iter().map(|&v| v as i32).collect();
    let coeffs_i32: Vec<i32> = coeffs.iter().map(|&v| v as i32).collect();
    let out = art
        .run_i32(&[(&plane_i32, &[h, w]), (&coeffs_i32, &[3, 3])])
        .unwrap();
    let want =
        conv3x3_plane_ref(&plane, h, w, &coeffs, q8, q8, 4, Rounding::Floor).unwrap();
    let got: Vec<i64> = out[0].iter().map(|&v| v as i64).collect();
    assert_eq!(got, want, "kernel artifact diverges from fixedpoint reference");
}

#[test]
fn pjrt_service_end_to_end_with_batching() {
    if !artifacts_ready() {
        return;
    }
    let spec = zoo::lenet_ish();
    let golden = GoldenCnn::new(spec.clone(), BlockKind::Conv3).unwrap();
    let svc = InferenceService::start_factory(
        || {
            let rt = Runtime::cpu()?;
            let art = rt.load_named(&artifacts_dir(), "lenet_q8")?;
            PjrtExecutor::from_artifact(art)
        },
        8,
    );
    let images = random_images(&spec, 5, 0xBA7C);
    for img in &images {
        let im32: Vec<i32> = img.iter().map(|&v| v as i32).collect();
        let got = svc.infer(im32).unwrap();
        let want: Vec<i32> =
            golden.infer(img).unwrap().into_iter().map(|v| v as i32).collect();
        assert_eq!(got, want, "service path diverges from golden");
    }
    let stats = svc.stats();
    assert_eq!(stats.requests, 5);
    svc.shutdown();
}

#[test]
fn artifact_metadata_is_complete() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    for name in ["lenet_q8", "tiny_q8", "slim_q6"] {
        let art = rt.load_named(&artifacts_dir(), name).unwrap();
        assert_eq!(art.meta.get("kind"), Some("network"), "{name}");
        assert!(art.meta.dims("input_shape").unwrap().len() == 4, "{name}");
        assert!(art.meta.get("classes").is_some(), "{name}");
    }
}
