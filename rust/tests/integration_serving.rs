//! Integration tests for the sharded multi-network serving layer
//! (`coordinator::shard` + `coordinator::router` on top of the reworked
//! batching service): concurrent routing correctness against the golden
//! model, bounded-admission backpressure, and fleet statistics aggregation.
//!
//! The backpressure tests use a *gated* executor — one that blocks until the
//! test releases it through a channel — so queue-full conditions are
//! constructed deterministically instead of with sleeps.

use convkit::cnn::{zoo, GoldenCnn};
use convkit::blocks::BlockKind;
use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{Shard, ShardSpec, ShardedService};
use convkit::util::error::{Error, Result};
use std::sync::{mpsc, Arc, Barrier};

fn image(spec: &convkit::cnn::NetworkSpec, seed: u64) -> Vec<i32> {
    spec.synthetic_images_i32(1, seed).pop().unwrap()
}

/// Executes one batch per token received on `gate`; blocks otherwise.
struct GatedExecutor {
    gate: mpsc::Receiver<()>,
    classes: usize,
}

impl BatchExecutor for GatedExecutor {
    fn infer_batch(&mut self, images: &[Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        self.gate.recv().map_err(|_| Error::Runtime("gate closed".into()))?;
        Ok(images.iter().map(|_| vec![0i32; self.classes]).collect())
    }

    fn label(&self) -> String {
        "gated".into()
    }
}

/// A single-shard fleet around a gated executor with `queue_cap` slots.
/// Returns the gate sender the test uses to release batches one by one.
fn gated_fleet(queue_cap: usize) -> (ShardedService, mpsc::Sender<()>) {
    let (gate_tx, gate_rx) = mpsc::channel();
    // batch_size 1 → every request is its own batch → one gate token each.
    let svc = InferenceService::start(GatedExecutor { gate: gate_rx, classes: 3 }, 1);
    let shard = Shard::from_service("gated_net", 0, queue_cap, svc);
    let fleet = ShardedService::from_shards(vec![shard]).unwrap();
    (fleet, gate_tx)
}

#[test]
fn concurrent_multi_network_routing_matches_golden() {
    let fleet = ShardedService::start(&[
        ShardSpec::golden("tiny_q8").with_replicas(2).with_batch_size(4),
        ShardSpec::golden("slim_q6").with_batch_size(4),
    ])
    .unwrap();
    assert_eq!(fleet.networks(), vec!["slim_q6", "tiny_q8"]);
    assert_eq!(fleet.shards().len(), 3);

    // Two client threads per network, interleaved through one front-end.
    let fleet_ref = &fleet;
    std::thread::scope(|scope| {
        for (net_idx, spec) in [zoo::tiny(), zoo::slim_q6()].into_iter().enumerate() {
            for client in 0..2u64 {
                let spec = spec.clone();
                scope.spawn(move || {
                    let golden = GoldenCnn::new(spec.clone(), BlockKind::Conv2).unwrap();
                    for r in 0..6u64 {
                        let seed = 1000 * (net_idx as u64 + 1) + 10 * client + r;
                        let im = image(&spec, seed);
                        let got = fleet_ref.infer(&spec.name, im.clone()).unwrap();
                        let want: Vec<i32> = golden
                            .infer(&im.iter().map(|&v| v as i64).collect::<Vec<_>>())
                            .unwrap()
                            .into_iter()
                            .map(|v| v as i32)
                            .collect();
                        assert_eq!(got, want, "{}: request {r} of client {client}", spec.name);
                    }
                });
            }
        }
    });

    // 4 clients × 6 requests, all answered, none failed, queues drained.
    let stats = fleet.stats();
    assert_eq!(stats.fleet.requests, 24);
    assert_eq!(stats.fleet.errors, 0);
    assert_eq!(stats.fleet.queue_depth, 0);
    assert!(stats.fleet.p95_latency_ms >= stats.shards[0].service.p95_latency_ms);
    // Per-network sums: tiny (2 replicas) served 12, slim served 12.
    let sum_for = |net: &str| -> u64 {
        stats.shards.iter().filter(|s| s.network == net).map(|s| s.service.requests).sum()
    };
    assert_eq!(sum_for("tiny_q8"), 12);
    assert_eq!(sum_for("slim_q6"), 12);
    fleet.shutdown();
}

#[test]
fn routing_unknown_network_is_rejected() {
    let fleet = ShardedService::start(&[ShardSpec::golden("tiny_q8")]).unwrap();
    let err = fleet.infer("no_such_net", vec![0; 64]).unwrap_err();
    assert!(matches!(err, Error::Usage(_)), "got {err}");
    assert!(err.to_string().contains("tiny_q8"), "should list known networks: {err}");
    fleet.shutdown();
}

#[test]
fn try_infer_rejects_at_cap_then_recovers_after_drain() {
    let (fleet, gate) = gated_fleet(2);

    // Fill both admission slots; the worker is blocked on the gate, so
    // neither completes until the test says so.
    let t1 = fleet.try_submit("gated_net", vec![1, 2, 3]).unwrap();
    let t2 = fleet.try_submit("gated_net", vec![4, 5, 6]).unwrap();
    assert_eq!(fleet.shards()[0].outstanding(), 2);

    // At cap: bounded admission rejects with Overloaded...
    let err = fleet.try_infer("gated_net", vec![7, 8, 9]).unwrap_err();
    assert!(matches!(err, Error::Overloaded(_)), "got {err}");
    assert!(err.to_string().contains("queue cap"), "{err}");
    // ...and rejection rolled its optimistic slot back.
    assert_eq!(fleet.shards()[0].outstanding(), 2);

    // Drain: release one batch per queued request, collect the replies.
    gate.send(()).unwrap();
    gate.send(()).unwrap();
    assert_eq!(t1.wait().unwrap(), vec![0, 0, 0]);
    assert_eq!(t2.wait().unwrap(), vec![0, 0, 0]);
    assert_eq!(fleet.shards()[0].outstanding(), 0);

    // Below cap again: admission succeeds end to end.
    gate.send(()).unwrap();
    assert_eq!(fleet.try_infer("gated_net", vec![1]).unwrap(), vec![0, 0, 0]);

    let stats = fleet.stats();
    assert_eq!(stats.fleet.requests, 3, "the rejected request never reached the worker");
    drop(gate);
    fleet.shutdown();
}

#[test]
fn try_submit_falls_back_across_replicas_in_load_order() {
    // Replica 0: cap 1. Replica 1: cap 4. Both gated (wedged), so loads are
    // fully deterministic — completions cannot race the assertions.
    let (gate0_tx, gate0_rx) = mpsc::channel();
    let (gate1_tx, gate1_rx) = mpsc::channel();
    let s0 = Shard::from_service(
        "net",
        0,
        1,
        InferenceService::start(GatedExecutor { gate: gate0_rx, classes: 1 }, 1),
    );
    let s1 = Shard::from_service(
        "net",
        1,
        4,
        InferenceService::start(GatedExecutor { gate: gate1_rx, classes: 1 }, 1),
    );
    let fleet = ShardedService::from_shards(vec![s0, s1]).unwrap();
    let shards = fleet.shards();

    // t0: tie (0, 0) → replica 0. t1: loads (1, 0) → replica 1.
    let t0 = fleet.try_submit("net", vec![1]).unwrap();
    let t1 = fleet.try_submit("net", vec![2]).unwrap();
    assert_eq!((shards[0].outstanding(), shards[1].outstanding()), (1, 1));

    // t2: tie (1, 1) prefers replica 0 — which is AT ITS CAP. Pre-retry
    // routing surfaced Overloaded here; now the router's fallback order
    // carries the request to replica 1, which has room. A redirected probe
    // is NOT a turned-away request, so no rejection is counted.
    let t2 = fleet.try_submit("net", vec![3]).unwrap();
    assert_eq!((shards[0].outstanding(), shards[1].outstanding()), (1, 2));
    assert_eq!(shards[0].rejected(), 0, "fallback admission is not a rejection");
    assert_eq!(shards[1].rejected(), 0);

    // Fill replica 1 to its cap through the same fallback path...
    let t3 = fleet.try_submit("net", vec![4]).unwrap();
    let t4 = fleet.try_submit("net", vec![5]).unwrap();
    assert_eq!((shards[0].outstanding(), shards[1].outstanding()), (1, 4));

    // ...and only with EVERY replica at cap does Overloaded surface —
    // counted exactly once, against the preferred replica.
    let err = fleet.try_submit("net", vec![6]).unwrap_err();
    assert!(matches!(err, Error::Overloaded(_)), "got {err}");
    assert_eq!(shards[0].rejected(), 1, "one turn-away, charged to the preferred replica");
    assert_eq!(shards[1].rejected(), 0);

    // The direct shard-level path still counts its own rejections.
    assert!(matches!(shards[0].try_submit(vec![9]), Err(Error::Overloaded(_))));
    assert_eq!(shards[0].rejected(), 2);

    // Drain everything (one gate token per batch; batch_size is 1).
    gate0_tx.send(()).unwrap();
    for _ in 0..4 {
        gate1_tx.send(()).unwrap();
    }
    for t in [t0, t1, t2, t3, t4] {
        assert_eq!(t.wait().unwrap(), vec![0]);
    }
    drop((gate0_tx, gate1_tx));
    fleet.shutdown();
}

#[test]
fn abandoned_ticket_keeps_slot_until_worker_completes() {
    let (fleet, gate) = gated_fleet(1);
    let ticket = fleet.try_submit("gated_net", vec![1]).unwrap();
    assert_eq!(fleet.shards()[0].outstanding(), 1);
    // Cap 1 → a second admission is rejected while the request is queued.
    assert!(matches!(fleet.try_submit("gated_net", vec![2]), Err(Error::Overloaded(_))));
    // Abandoning the reply does NOT free the slot: the request still sits in
    // the worker's queue, so the cap keeps bounding real backlog — a client
    // looping try_submit/drop cannot grow the queue past the cap.
    drop(ticket);
    assert_eq!(fleet.shards()[0].outstanding(), 1);
    assert!(matches!(fleet.try_submit("gated_net", vec![3]), Err(Error::Overloaded(_))));
    // Only worker-side completion releases the slot (bounded wait: the
    // worker drops the guard as soon as the gated batch executes).
    gate.send(()).unwrap();
    let mut released = false;
    for _ in 0..2000 {
        if fleet.shards()[0].outstanding() == 0 {
            released = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(released, "worker completion must release the abandoned slot");
    drop(gate);
    fleet.shutdown();
}

#[test]
fn blocking_submit_is_not_capped() {
    let (fleet, gate) = gated_fleet(1);
    // submit() bypasses the cap (cooperative clients): three concurrent
    // tickets on a cap-1 shard.
    let tickets: Vec<_> =
        (0..3).map(|i| fleet.submit("gated_net", vec![i]).unwrap()).collect();
    assert_eq!(fleet.shards()[0].outstanding(), 3);
    for _ in 0..3 {
        gate.send(()).unwrap();
    }
    for t in tickets {
        assert_eq!(t.wait().unwrap(), vec![0, 0, 0]);
    }
    assert_eq!(fleet.shards()[0].outstanding(), 0);
    drop(gate);
    fleet.shutdown();
}

#[test]
fn stats_of_wedged_worker_are_answered_instantly_from_the_mirror() {
    // The lock-free stats contract: snapshots come from the worker's atomic
    // counter mirror, so a worker blocked inside its executor cannot wedge a
    // monitor (the old message round-trip degraded to a `stale` row after a
    // timeout; the mirror is simply always current).
    let (fleet, gate) = gated_fleet(4);
    let ticket = fleet.try_submit("gated_net", vec![1]).unwrap();
    let t0 = std::time::Instant::now();
    let row = fleet.shards()[0].stats();
    assert!(
        t0.elapsed() < std::time::Duration::from_millis(100),
        "snapshot must be a memory read, not a worker round-trip"
    );
    assert!(!row.stale, "mirror snapshots are never stale");
    assert_eq!(row.queue_depth, 1);
    assert_eq!(row.service.requests, 0, "the wedged request has not completed");
    // Unwedge; a fresh snapshot sees the completed request.
    gate.send(()).unwrap();
    assert_eq!(ticket.wait().unwrap(), vec![0, 0, 0]);
    let row = fleet.shards()[0].stats();
    assert_eq!(row.service.requests, 1);
    let fleet_stats = fleet.stats();
    assert_eq!(fleet_stats.fleet.stale_shards, 0);
    drop(gate);
    fleet.shutdown();
}

#[test]
fn lockfree_admission_never_exceeds_queue_cap_under_a_barrier_storm() {
    // PR 6 acceptance: `try_submit` takes no locks on the request path —
    // admission is an optimistic SeqCst slot reservation rolled back on
    // overflow. A barrier releases 8 threads into a cap-4 shard at once;
    // however the interleaving falls, exactly `cap` must be admitted and the
    // rest turned away, with the outstanding count never exceeding the cap.
    const CAP: usize = 4;
    const THREADS: usize = 8;
    let (fleet, gate) = gated_fleet(CAP);
    let barrier = Barrier::new(THREADS);
    let (fleet_ref, barrier_ref) = (&fleet, &barrier);
    let tickets: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                scope.spawn(move || {
                    barrier_ref.wait();
                    match fleet_ref.try_submit("gated_net", vec![i as i32]) {
                        Ok(t) => Some(t),
                        Err(Error::Overloaded(_)) => None,
                        Err(e) => panic!("unexpected admission error: {e}"),
                    }
                })
            })
            .collect();
        handles.into_iter().filter_map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(tickets.len(), CAP, "exactly the queue cap is admitted");
    assert_eq!(fleet.shards()[0].outstanding(), CAP);
    assert_eq!(fleet.shards()[0].rejected(), (THREADS - CAP) as u64);
    // Drain: batch_size 1 → one gate token per admitted request.
    for _ in 0..CAP {
        gate.send(()).unwrap();
    }
    for t in tickets {
        assert_eq!(t.wait().unwrap(), vec![0, 0, 0]);
    }
    assert_eq!(fleet.shards()[0].outstanding(), 0);
    drop(gate);
    fleet.shutdown();
}

#[test]
fn replicas_share_load_by_outstanding_count() {
    // Two gated replicas of one network, cap 4 each: with replica 0 wedged
    // (one outstanding request), new admissions route to replica 1.
    let (gate0_tx, gate0_rx) = mpsc::channel();
    let (gate1_tx, gate1_rx) = mpsc::channel();
    let s0 = Shard::from_service(
        "gated_net",
        0,
        4,
        InferenceService::start(GatedExecutor { gate: gate0_rx, classes: 1 }, 1),
    );
    let s1 = Shard::from_service(
        "gated_net",
        1,
        4,
        InferenceService::start(GatedExecutor { gate: gate1_rx, classes: 1 }, 1),
    );
    let fleet = ShardedService::from_shards(vec![s0, s1]).unwrap();

    // Tie (0 vs 0) → lowest index: replica 0 takes the first request.
    let t0 = fleet.try_submit("gated_net", vec![1]).unwrap();
    assert_eq!(fleet.shards()[0].outstanding(), 1);
    assert_eq!(fleet.shards()[1].outstanding(), 0);
    // Load 1 vs 0 → replica 1 takes the next two (released immediately).
    gate1_tx.send(()).unwrap();
    assert_eq!(fleet.try_infer("gated_net", vec![2]).unwrap(), vec![0]);
    gate1_tx.send(()).unwrap();
    assert_eq!(fleet.try_infer("gated_net", vec![3]).unwrap(), vec![0]);
    assert_eq!(fleet.shards()[1].stats().service.requests, 2);

    // Unwedge replica 0 before querying its stats (a worker blocked inside
    // its executor cannot answer until the batch returns).
    gate0_tx.send(()).unwrap();
    assert_eq!(t0.wait().unwrap(), vec![0]);
    assert_eq!(fleet.shards()[0].stats().service.requests, 1);
    drop((gate0_tx, gate1_tx));
    fleet.shutdown();
}
