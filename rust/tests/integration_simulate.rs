//! Integration tests for the virtual-clock traffic simulator:
//!
//! * **Determinism** — the same seed + scenario produces a byte-identical
//!   capacity report, across every scenario shape (the property that lets
//!   CI archive and diff the JSON).
//! * **Admission fidelity** — the simulated engine's Overloaded/fallback
//!   ordering is cross-checked against a REAL gated-executor
//!   `ShardedService` driven with the same tiny trace: same admitted-replica
//!   sequence, same rejection accounting.
//! * **Shared policy path** — one `Autoscaler` type drives both a live
//!   fleet (via `LiveFleet`) and the simulator (via `SimFleet`) through the
//!   same `step_target` code, producing the same justified decision.

use convkit::cnn::zoo;
use convkit::coordinator::dse::DseEngine;
use convkit::coordinator::jobs::JobPool;
use convkit::coordinator::service::{BatchExecutor, InferenceService};
use convkit::coordinator::{Shard, ShardSpec, ShardedService};
use convkit::fleetplan::{
    Autoscaler, FleetPlan, LiveFleet, NetworkDemand, NetworkPlan, ScaleAction, SloPolicy,
};
use convkit::models::{ModelRegistry, SelectOptions};
use convkit::platform::Platform;
use convkit::simulate::{
    explore, simulate_trace, Admission, Scenario, ScenarioShape, SimFleet, SimRunOptions,
    SimServiceModel, WhatIfOptions,
};
use convkit::synth::ResourceVector;
use convkit::synthdata::SweepOptions;
use convkit::util::error::{Error, Result};
use std::sync::mpsc;

fn registry() -> ModelRegistry {
    let eng = DseEngine {
        sweep: SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() },
        select: SelectOptions::default(),
        pool: JobPool::with_workers(2),
        cache: None,
    };
    eng.run().unwrap().registry
}

fn test_options() -> WhatIfOptions {
    WhatIfOptions {
        // Small + fast: a few thousand arrivals, tight control cadence so
        // the controller runs many times inside the short virtual window.
        min_arrivals: 4_000,
        probe_arrivals: 800,
        control_interval_ms: 0.25,
        ..WhatIfOptions::default()
    }
}

#[test]
fn explore_is_byte_deterministic_per_seed_and_differs_across_seeds() {
    let reg = registry();
    let demands =
        [NetworkDemand::new(zoo::tiny()), NetworkDemand::new(zoo::slim_q6())];
    let platforms = Platform::all();
    let opts = test_options();
    for shape in [ScenarioShape::Steady, ScenarioShape::Burst, ScenarioShape::HeavyTail] {
        let scenario = Scenario::new(shape, Vec::new(), 0.0, 0.0, 42);
        let a = explore(&demands, &reg, &platforms, &scenario, &opts).unwrap();
        let b = explore(&demands, &reg, &platforms, &scenario, &opts).unwrap();
        assert_eq!(
            a.to_json(),
            b.to_json(),
            "{shape:?}: same seed + scenario must produce a byte-identical report"
        );
        let other = Scenario::new(shape, Vec::new(), 0.0, 0.0, 43);
        let c = explore(&demands, &reg, &platforms, &other, &opts).unwrap();
        assert_ne!(a.to_json(), c.to_json(), "{shape:?}: different seed must diverge");
    }
}

#[test]
fn capacity_report_names_platform_qps_trajectory_and_p95() {
    let reg = registry();
    let demands =
        [NetworkDemand::new(zoo::tiny()), NetworkDemand::new(zoo::slim_q6())];
    let scenario = Scenario::new(ScenarioShape::Burst, Vec::new(), 0.0, 0.0, 42);
    let r = explore(&demands, &reg, &Platform::all(), &scenario, &test_options()).unwrap();
    assert!(!r.platform.is_empty(), "a platform must be selected");
    assert!(r.max_sustainable_qps > 0.0, "{r:?}");
    // ~4k arrivals (Poisson-sized) + per-BATCH completions + control ticks:
    // the floor is looser than the arrival target because coalescing turned
    // per-request completions into per-batch ones.
    assert!(r.events > 3_500, "arrivals + service events + ticks: {}", r.events);
    assert_eq!(r.networks.len(), 2);
    for n in &r.networks {
        assert!(n.offered > 0, "{n:?}");
        assert!(n.p95_ms > 0.0, "predicted p95 per network: {n:?}");
        assert!(n.p95_ms >= 0.5 * n.predicted_ms, "tail ~≥ one service time: {n:?}");
        assert!(n.peak_replicas >= n.start_replicas as usize);
    }
    assert!(!r.trajectory.is_empty(), "initial replica counts are recorded");
    // An 8× burst over floors sized to 1.5× mean load must overload the
    // floor fleet: the (production) controller has to scale up.
    assert!(r.scale_ups > 0, "burst must trigger scale-ups: {r:?}");
    // The report renders without panicking and mentions the essentials.
    let text = convkit::report::capacity_table(&r);
    assert!(text.contains(&r.platform));
    assert!(text.contains("max sustainable"));
}

/// Executes one batch per token received on `gate`; blocks otherwise (the
/// deterministic way to hold a live queue full — no sleeps).
struct GatedExecutor {
    gate: mpsc::Receiver<()>,
    classes: usize,
}

impl BatchExecutor for GatedExecutor {
    fn infer_batch(&mut self, images: &[std::sync::Arc<[i32]>]) -> Result<Vec<Vec<i32>>> {
        self.gate.recv().map_err(|_| Error::Runtime("gate closed".into()))?;
        Ok(images.iter().map(|_| vec![0i32; self.classes]).collect())
    }

    fn label(&self) -> String {
        "gated".into()
    }
}

fn gated_shard(network: &str, replica: usize, cap: usize) -> (Shard, mpsc::Sender<()>) {
    let (tx, rx) = mpsc::channel();
    let svc = InferenceService::start(GatedExecutor { gate: rx, classes: 1 }, 1);
    (Shard::from_service(network, replica, cap, svc), tx)
}

#[test]
fn simulated_admission_matches_a_real_gated_fleet_on_the_same_trace() {
    // Real fleet: two wedged replicas of one network, caps 1 and 4 — loads
    // are fully deterministic because nothing ever completes.
    let (s0, gate0) = gated_shard("net", 0, 1);
    let (s1, gate1) = gated_shard("net", 1, 4);
    let live = ShardedService::from_shards(vec![s0, s1]).unwrap();

    // Simulated twin: same caps, a service time so large nothing completes
    // within the trace.
    let mut sim = SimFleet::new(&[SimServiceModel {
        service_ns: u64::MAX / 4,
        ..SimServiceModel::new("net", 1.0, 1, 0)
    }])
    .unwrap();
    sim.push_replica("net", 1, u64::MAX / 4);
    sim.push_replica("net", 4, u64::MAX / 4);

    // The same tiny trace through both admission paths. For the live fleet
    // the admitting replica is recovered from the outstanding-count deltas.
    let mut live_outcomes: Vec<Option<usize>> = Vec::new();
    for i in 0..6u64 {
        let before: Vec<usize> =
            live.shards().iter().map(|s| s.outstanding()).collect();
        match live.try_submit("net", vec![i as i32]) {
            Ok(_ticket) => {
                let after: Vec<usize> =
                    live.shards().iter().map(|s| s.outstanding()).collect();
                let who = (0..after.len())
                    .find(|&k| after[k] > before[k])
                    .expect("an admission must land somewhere");
                live_outcomes.push(Some(live.shards()[who].replica));
            }
            Err(Error::Overloaded(_)) => live_outcomes.push(None),
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    let sim_outcomes: Vec<Option<usize>> = (0..6u64)
        .map(|i| match sim.offer("net", i).unwrap() {
            Admission::Admitted { replica } => Some(replica),
            Admission::Rejected => None,
            Admission::Shed => unreachable!("interactive offers are never shed"),
        })
        .collect();
    assert_eq!(
        live_outcomes, sim_outcomes,
        "simulated Overloaded/fallback ordering must match the live fleet"
    );
    // Identical rejection accounting: one turn-away, charged to the
    // preferred replica in both worlds.
    let live_rejected: Vec<u64> =
        live.shards().iter().map(|s| s.rejected()).collect();
    let sim_stats = sim.stats();
    let sim_rejected: Vec<u64> = sim_stats.shards.iter().map(|s| s.rejected).collect();
    assert_eq!(live_rejected, vec![1, 0]);
    assert_eq!(sim_rejected, vec![1, 0]);

    // Release the live workers so shutdown joins cleanly.
    let _ = gate0.send(());
    for _ in 0..4 {
        let _ = gate1.send(());
    }
    drop((gate0, gate1));
    live.shutdown();
}

/// Hand-built plan: one network priced at 100 DSP per replica on a ZCU104.
fn tiny_plan() -> FleetPlan {
    let platform = Platform::zcu104();
    let unit = ResourceVector::new(1_000, 0, 0, 0, 100);
    FleetPlan {
        platform: platform.clone(),
        cap: 0.8,
        networks: vec![NetworkPlan {
            network: "tiny_q8".into(),
            unit,
            predicted_ms: 1.0,
            fill_ms: 0.1,
            util_frac: 100.0 / 1382.0,
            replicas: 13,
            min_replicas: 1,
            max_replicas: 0,
            weight: 1.0,
        }],
        total: unit.scaled(13),
        utilization: platform.utilization(&unit.scaled(13)),
    }
}

fn policy() -> SloPolicy {
    SloPolicy { window: 1, ..SloPolicy::default() }
}

#[test]
fn one_controller_code_path_drives_both_live_fleet_and_simulator() {
    let templates = vec![ShardSpec::golden("tiny_q8").with_queue_cap(1)];

    // --- live side: a cap-1 gated shard named like the planned network ---
    let (shard, gate) = gated_shard("tiny_q8", 0, 1);
    let live = ShardedService::from_shards(vec![shard]).unwrap();
    let t = live.try_submit("tiny_q8", vec![1]).unwrap();
    assert!(matches!(live.try_submit("tiny_q8", vec![2]), Err(Error::Overloaded(_))));
    gate.send(()).unwrap(); // let the admitted request finish so stats answer fast
    t.wait().unwrap();
    let mut live_scaler = Autoscaler::new(tiny_plan(), policy(), templates.clone());
    let live_decisions =
        live_scaler.step_target(&mut LiveFleet::new(&live)).unwrap();
    assert_eq!(live.replica_count("tiny_q8"), 2, "live scale-up actuated");

    // --- simulated side: the same overload story on virtual time ---------
    let mut sim =
        SimFleet::new(&[SimServiceModel::new("tiny_q8", 1.0, 1, 1)]).unwrap();
    sim.offer("tiny_q8", 0).unwrap();
    assert_eq!(sim.offer("tiny_q8", 0).unwrap(), Admission::Rejected);
    sim.drain(); // the admitted request completes, mirroring the gate release
    let mut sim_scaler = Autoscaler::new(tiny_plan(), policy(), templates);
    let sim_decisions = sim_scaler.step_target(&mut sim).unwrap();
    assert_eq!(sim.replica_count("tiny_q8"), 2, "simulated scale-up actuated");

    // Same policy path ⇒ same justified decision on both targets.
    assert_eq!(live_decisions.len(), 1);
    assert_eq!(sim_decisions.len(), 1);
    let (l, s) = (&live_decisions[0], &sim_decisions[0]);
    assert_eq!(l.network, s.network);
    assert_eq!(l.action, ScaleAction::Up);
    assert_eq!(s.action, ScaleAction::Up);
    assert_eq!((l.from_replicas, l.to_replicas), (s.from_replicas, s.to_replicas));
    assert_eq!(l.predicted_total, s.predicted_total, "same model-predicted justification");

    drop(gate);
    live.shutdown();
}

#[test]
fn packed_device_sustains_measurably_lower_qps_monotone_in_colocation() {
    // The contention cross-check: the same offered trace drained by k
    // replicas, co-located on one device (each holding 25% of its capped
    // budget) vs uncontended. Offered load saturates every configuration,
    // so completed-per-virtual-second reads the service capacity directly.
    let scenario = Scenario::new(
        ScenarioShape::Steady,
        vec![("a".to_string(), 1.0)],
        6_000.0,
        500.0,
        11,
    );
    let trace = scenario.arrivals();
    let sustained = |colocated: bool, k: usize| {
        let mut m = SimServiceModel::new("a", 1.0, 64, k);
        if colocated {
            m = m.on_platform("dev", 0.25);
        }
        let mut f = SimFleet::new(&[m]).unwrap();
        let run =
            simulate_trace(&mut f, &trace, &mut [], &SimRunOptions::default()).unwrap();
        assert_eq!(run.completed, run.admitted);
        run.completed as f64 / (run.virtual_ms / 1e3)
    };
    // Packed < uncontended at every co-located replica count.
    for k in 2..=4usize {
        let packed = sustained(true, k);
        let lone = sustained(false, k);
        assert!(
            packed < lone * 0.97,
            "k={k}: packed device must sustain measurably less ({packed:.0} vs {lone:.0} qps)"
        );
    }
    // Monotone: per-replica capacity falls as the device packs
    // (1 + α × 0.25 × (k − 1) slowdown per replica).
    let mut last = f64::INFINITY;
    for k in 1..=4usize {
        let per_replica = sustained(true, k) / k as f64;
        assert!(
            per_replica < last * 0.98,
            "k={k}: per-replica rate must degrade monotonically \
             ({per_replica:.0} vs previous {last:.0})"
        );
        last = per_replica;
    }
}

#[test]
fn batched_engine_matches_live_coalescing_semantics_under_backlog() {
    // Five requests dumped on one idle replica, batch cap 4: the live
    // worker serves 1 (blocking recv) then coalesces the backlog of 4; the
    // virtual replica must form exactly the same batches.
    let model = SimServiceModel::new("a", 1.0, 8, 1).with_batching(4, 0.25);
    let mut f = SimFleet::new(&[model]).unwrap();
    for _ in 0..5 {
        f.offer("a", 0).unwrap();
    }
    f.drain();
    let s = f.stats();
    assert_eq!(s.shards[0].service.requests, 5);
    assert_eq!(s.shards[0].service.batches, 2, "1 blocking + 4 coalesced");
    // The second batch rides the amortized curve: fill once (0.25 ms) +
    // 4 × 0.75 ms drain, after the first 1 ms service → 4.25 ms total.
    assert!((f.now_ms() - 4.25).abs() < 1e-6, "{}", f.now_ms());
}

#[test]
fn recorded_style_traces_replay_through_the_engine() {
    // A replay-shaped trace (as `drive_golden_clients_traced` would record)
    // runs the engine exactly like a synthetic one.
    let scenario = Scenario::new(
        ScenarioShape::Steady,
        vec![("a".to_string(), 1.0)],
        2_000.0,
        500.0,
        7,
    );
    let trace = scenario.arrivals();
    let mut fleet = SimFleet::new(&[SimServiceModel::new("a", 0.4, 8, 2)]).unwrap();
    let run =
        simulate_trace(&mut fleet, &trace, &mut [], &SimRunOptions::default()).unwrap();
    assert_eq!(run.offered as usize, trace.len());
    assert_eq!(run.completed, run.admitted, "every admitted request drains");
    assert!(run.virtual_ms >= trace.duration_ms());
}
