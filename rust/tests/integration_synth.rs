//! Integration: the full synthesis campaign and its statistical structure —
//! the paper's §3.2/§3.3 pipeline over the real (default, jittered) sweep.

use convkit::blocks::BlockKind;
use convkit::stats::pearson;
use convkit::synth::Resource;
use convkit::synthdata::{run_sweep, SweepOptions};

fn full_dataset() -> convkit::synthdata::Dataset {
    run_sweep(&SweepOptions::default()).unwrap()
}

#[test]
fn campaign_has_196_configs_per_block() {
    let ds = full_dataset();
    assert_eq!(ds.len(), BlockKind::ALL.len() * 196);
    for b in BlockKind::ALL {
        assert_eq!(ds.for_block(b).len(), 196, "{b}");
    }
}

#[test]
fn dsp_counts_structural_everywhere() {
    let ds = full_dataset();
    for r in &ds.records {
        assert_eq!(r.res.dsp, r.block.dsp_count(), "{:?}", r);
    }
}

#[test]
fn conv1_is_the_logic_block() {
    // Table 2's qualitative classes, quantified: at every configuration,
    // Conv1 uses the most logic and zero DSPs; Conv2 the least logic of the
    // DSP blocks at 8/8.
    let ds = full_dataset();
    for d in [3u32, 8, 16] {
        for c in [3u32, 8, 16] {
            let llut = |b: BlockKind| ds.get(b, d, c).unwrap().res.llut;
            // The d·c array multiplier grows fast: ≥2x Conv2 from 5 bits up,
            // and still clearly bigger at the 3-bit floor.
            let factor = if d >= 5 && c >= 5 { 2 } else { 1 };
            assert!(
                llut(BlockKind::Conv1) > factor * llut(BlockKind::Conv2),
                "d={d} c={c}: {} vs {}",
                llut(BlockKind::Conv1),
                llut(BlockKind::Conv2)
            );
        }
    }
    let r8 = |b: BlockKind| ds.get(b, 8, 8).unwrap().res.llut;
    assert!(r8(BlockKind::Conv2) <= r8(BlockKind::Conv3));
    assert!(r8(BlockKind::Conv2) <= r8(BlockKind::Conv4));
}

#[test]
fn paper_magnitude_anchors_at_8_8() {
    // DESIGN.md §2 calibration: paper-reported magnitudes at 8-bit/8-bit.
    let ds = full_dataset();
    let r = |b: BlockKind| ds.get(b, 8, 8).unwrap().res;
    let c1 = r(BlockKind::Conv1);
    assert!((80..=220).contains(&c1.llut), "Conv1 LLUT {}", c1.llut); // paper 104
    assert!((30..=70).contains(&c1.ff), "Conv1 FF {}", c1.ff); // paper 53
    assert!((5..=30).contains(&c1.cchain), "Conv1 CChain {}", c1.cchain); // paper 9.3
    let c2 = r(BlockKind::Conv2);
    assert!((15..=45).contains(&c2.llut), "Conv2 LLUT {}", c2.llut); // paper ~25
    let c4 = r(BlockKind::Conv4);
    assert!((25..=60).contains(&c4.llut), "Conv4 LLUT {}", c4.llut); // paper ~37
}

#[test]
fn table3_correlation_signs_and_magnitudes() {
    let ds = full_dataset();
    let corr = |b: BlockKind, res: Resource, which: usize| {
        let (d, c, ys) = ds.columns(b);
        let idx = Resource::ALL.iter().position(|&r| r == res).unwrap();
        let x = if which == 0 { &d } else { &c };
        pearson(x, &ys[idx])
    };
    // Conv1/Conv2: LLUT strongly correlated with BOTH widths (paper ~0.66-0.71).
    for b in [BlockKind::Conv1, BlockKind::Conv2] {
        assert!(corr(b, Resource::Llut, 0) > 0.5, "{b} d");
        assert!(corr(b, Resource::Llut, 1) > 0.5, "{b} c");
    }
    // Conv1 near-symmetric (paper: 0.668 vs 0.672).
    let (cd, cc) =
        (corr(BlockKind::Conv1, Resource::Llut, 0), corr(BlockKind::Conv1, Resource::Llut, 1));
    assert!((cd - cc).abs() < 0.15, "Conv1 symmetry: {cd} vs {cc}");
    // Conv3: EXACTLY zero against data width, for every resource.
    for res in Resource::ALL {
        assert!(
            corr(BlockKind::Conv3, res, 0).abs() < 1e-9,
            "Conv3 {} vs d",
            res.name()
        );
    }
    // Conv2/Conv4 FF: zero vs data, ~1 vs coeff (paper 0.000 / 0.997).
    for b in [BlockKind::Conv2, BlockKind::Conv4] {
        assert!(corr(b, Resource::Ff, 0).abs() < 0.05, "{b} FF vs d");
        assert!(corr(b, Resource::Ff, 1) > 0.95, "{b} FF vs c");
    }
}

#[test]
fn jitter_bounded_relative_to_exact() {
    use convkit::blocks::{synthesize, ConvBlockConfig};
    use convkit::synth::MapOptions;
    for b in BlockKind::ALL {
        for (d, c) in [(3, 3), (8, 8), (16, 16)] {
            let cfg = ConvBlockConfig::new(b, d, c).unwrap();
            let exact = synthesize(&cfg, &MapOptions::exact());
            let jit = synthesize(&cfg, &MapOptions::default());
            let rel = (jit.llut as f64 - exact.llut as f64).abs() / exact.llut.max(1) as f64;
            assert!(rel <= 0.05, "{cfg}: jitter {rel}");
            assert_eq!(jit.mlut, exact.mlut, "{cfg}: MLUT is structural");
            assert_eq!(jit.cchain, exact.cchain, "{cfg}: CChain is structural");
            assert_eq!(jit.dsp, exact.dsp, "{cfg}: DSP is structural");
        }
    }
}

#[test]
fn every_netlist_in_the_sweep_validates() {
    use convkit::synthdata::sweep_configs;
    for cfg in sweep_configs(&SweepOptions::default()) {
        cfg.elaborate().validate().unwrap_or_else(|e| panic!("{cfg}: {e}"));
    }
}
