//! Property-based suite (via the in-crate `util::proptest` harness): the
//! invariants that must hold for *every* configuration, not just the sampled
//! corners.

use convkit::blocks::{synthesize, BlockKind, ConvBlockConfig, FuncSim};
use convkit::fixedpoint::{conv3x3_ref, QFormat, Rounding};
use convkit::polyapprox::{ulp_eps, ActFn, FixedActivation, PolyDegree};
use convkit::synth::MapOptions;
use convkit::util::proptest::{forall, shrink_pair, Config};
use convkit::util::rng::SplitMix64;

fn cfg_of(kind: BlockKind, d: i64, c: i64) -> ConvBlockConfig {
    ConvBlockConfig::new(kind, d as u32, c as u32).unwrap()
}

fn width_pair() -> impl Fn(&mut SplitMix64) -> (i64, i64) {
    |rng| (rng.range_i64(3, 16), rng.range_i64(3, 16))
}

#[test]
fn prop_every_block_funcsim_matches_reference() {
    // For any widths, any shift, any stimulus: EVERY registered block's
    // functional simulator computes exactly conv3x3_ref composed with the
    // configuration's activation stage. Datapath domain constraints
    // (Conv3's packed 8-bit arithmetic) come from the registry, not from
    // per-block special cases here.
    for kind in BlockKind::ALL {
        forall(
            &Config { cases: 48, ..Default::default() },
            &format!("{kind} funcsim == reference"),
            width_pair(),
            shrink_pair(3),
            |&(d, c)| {
                let blk = kind.block();
                let d = d.min(blk.effective_data_bits(d as u32) as i64);
                let c = c.min(blk.max_coeff_bits() as i64);
                let cfg = cfg_of(kind, d, c).with_shift((c / 2) as u32);
                let dq = cfg.data_q();
                let cq = cfg.coeff_q();
                let act = cfg.activation.bind(cfg.effective_data_bits());
                let mut rng = SplitMix64::new((d * 100 + c) as u64);
                let n_sets = blk.required_coeff_sets();
                let sets: Vec<[i64; 9]> = (0..n_sets)
                    .map(|_| std::array::from_fn(|_| rng.range_i64(cq.min(), cq.max())))
                    .collect();
                let windows: Vec<[i64; 9]> = (0..6)
                    .map(|_| std::array::from_fn(|_| rng.range_i64(dq.min(), dq.max())))
                    .collect();
                let mut sim = FuncSim::new(cfg);
                sim.load_coefficients(&sets).map_err(|e| e.to_string())?;
                let out = sim.process(&windows).map_err(|e| e.to_string())?;
                for (lane, set) in out.lanes.iter().zip(sets.iter().cycle()) {
                    for (i, win) in windows.iter().enumerate() {
                        let conv = conv3x3_ref(win, set, dq, cq, cfg.shift, Rounding::Floor)
                            .map_err(|e| e.to_string())?;
                        let want = act.apply(conv);
                        if lane[i] != want {
                            return Err(format!("window {i}: {} != {want}", lane[i]));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_activation_error_under_documented_ulp_bound() {
    // For any width and any input, the fixed-point polynomial activations
    // stay within `2 + ceil(ε·2^(d-1))` ULP of the rounded f64 reference,
    // with ε per (function, degree) as documented in polyapprox::ULP_EPS.
    for f in ActFn::ALL {
        for degree in [PolyDegree::Two, PolyDegree::Three] {
            forall(
                &Config { cases: 40, ..Default::default() },
                &format!("{}{} ULP bound", f.name(), degree.as_u32()),
                |rng| (rng.range_i64(3, 16), rng.range_i64(0, 1 << 20)),
                shrink_pair(0),
                |&(d, seed)| {
                    let d = d.clamp(3, 16) as u32;
                    let a = FixedActivation::new(f, degree, d);
                    let bound = a.ulp_bound();
                    let q = QFormat::new(d).map_err(|e| e.to_string())?;
                    let mut rng = SplitMix64::new(seed as u64);
                    for _ in 0..64 {
                        let x = rng.range_i64(q.min(), q.max());
                        let err = (a.eval(x) - a.reference(x)).abs();
                        if err > bound {
                            return Err(format!(
                                "eps {}: x={x} err {err} > bound {bound} at d={d}",
                                ulp_eps(f, degree)
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_resources_monotone_in_widths_exact_mapping() {
    // With jitter off, widening either operand never shrinks any resource
    // (Conv3's data width exempted: it is structurally inert there).
    for kind in BlockKind::ALL {
        forall(
            &Config { cases: 40, ..Default::default() },
            &format!("{kind} resource monotonicity"),
            |rng| (rng.range_i64(3, 15), rng.range_i64(3, 15)),
            shrink_pair(3),
            |&(d, c)| {
                let base = synthesize(&cfg_of(kind, d, c), &MapOptions::exact());
                let wd = synthesize(&cfg_of(kind, d + 1, c), &MapOptions::exact());
                let wc = synthesize(&cfg_of(kind, d, c + 1), &MapOptions::exact());
                for (label, a, b) in [
                    ("llut+d", base.llut, wd.llut),
                    ("llut+c", base.llut, wc.llut),
                    ("mlut+d", base.mlut, wd.mlut),
                    ("mlut+c", base.mlut, wc.mlut),
                    ("ff+c", base.ff, wc.ff),
                ] {
                    if b < a {
                        return Err(format!("{label}: {a} -> {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_narrow_bounds_and_monotone() {
    // narrow() output always lies in range, and is monotone in the input.
    forall(
        &Config { cases: 200, ..Default::default() },
        "narrow bounds + monotonicity",
        |rng| (rng.range_i64(2, 16), rng.range_i64(0, 20)),
        shrink_pair(0),
        |&(bits, shift)| {
            let bits = bits.max(2);
            let q = QFormat::new(bits as u32).map_err(|e| e.to_string())?;
            let mut rng = SplitMix64::new((bits * 31 + shift) as u64);
            let mut prev_in = i64::MIN;
            let mut prev_out = i64::MIN;
            let mut samples: Vec<i64> =
                (0..50).map(|_| rng.range_i64(-(1 << 30), 1 << 30)).collect();
            samples.sort_unstable();
            for v in samples {
                let out = q.narrow(v, shift as u32, Rounding::Floor);
                if !q.contains(out) {
                    return Err(format!("out of range: narrow({v}) = {out}"));
                }
                if v >= prev_in && out < prev_out {
                    return Err(format!("non-monotone at {v}"));
                }
                prev_in = v;
                prev_out = out;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allocator_never_exceeds_budget() {
    use convkit::allocate::allocate_mix;
    use convkit::platform::Platform;
    use convkit::synth::ResourceVector;
    forall(
        &Config { cases: 60, ..Default::default() },
        "allocator respects budgets",
        |rng| (rng.range_i64(1, 500), rng.range_i64(0, 3)),
        shrink_pair(0),
        |&(scale, dsp)| {
            let unit = [
                ResourceVector::new(scale as u64 + 50, 20, 40, 5, 0),
                ResourceVector::new(25, 30, 21, 0, dsp.max(1) as u64),
                ResourceVector::new(36, 28, 22, 0, 1),
                ResourceVector::new(37, 40, 25, 0, 2),
                ResourceVector::new(60, 30, 45, 3, 2),
            ];
            let p = Platform::zcu104();
            let mix = allocate_mix(&unit, &p, 0.8).map_err(|e| e.to_string())?;
            if !mix.usage(&unit).fits_within(&p.capped_budget(0.8)) {
                return Err(format!("over budget: {mix:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_polyfit_recovers_planted_linear_models() {
    use convkit::stats::PolyModel;
    forall(
        &Config { cases: 60, ..Default::default() },
        "polyfit recovers planted coefficients",
        |rng| (rng.range_i64(-50, 50), rng.range_i64(-50, 50)),
        shrink_pair(-50),
        |&(a, b)| {
            let a = a as f64 / 10.0;
            let b = b as f64 / 10.0;
            let samples: Vec<(f64, f64, f64)> = (3..=16)
                .flat_map(|d| {
                    (3..=16).map(move |c| {
                        (d as f64, c as f64, 7.5 + a * d as f64 + b * c as f64)
                    })
                })
                .collect();
            let m = PolyModel::fit(&samples, 1).map_err(|e| e.to_string())?;
            let got_a = m.terms.iter().find(|t| t.dx == 1).map(|t| t.coef).unwrap_or(0.0);
            let got_b = m.terms.iter().find(|t| t.cx == 1).map(|t| t.coef).unwrap_or(0.0);
            if (got_a - a).abs() > 1e-8 || (got_b - b).abs() > 1e-8 {
                return Err(format!("recovered ({got_a}, {got_b}) != planted ({a}, {b})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_segmented_fit_never_worse_than_single_line() {
    use convkit::stats::SegmentedModel;
    forall(
        &Config { cases: 60, ..Default::default() },
        "segmented >= single-line quality",
        |rng| (rng.range_i64(1, 1000), rng.range_i64(2, 6)),
        shrink_pair(1),
        |&(seed, segs)| {
            let mut rng = SplitMix64::new(seed as u64);
            let pts: Vec<(f64, f64)> = (3..=16)
                .map(|c| (c as f64, rng.range_i64(0, 100) as f64))
                .collect();
            let one = SegmentedModel::fit(&pts, 1).map_err(|e| e.to_string())?;
            let multi = SegmentedModel::fit(&pts, segs as usize).map_err(|e| e.to_string())?;
            if multi.r2 + 1e-9 < one.r2 {
                return Err(format!("multi {} < single {}", multi.r2, one.r2));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_golden_cnn_logits_bounded() {
    // For any input, logits are bounded by (relu-max · spatial) >> head_shift
    // and non-negative — the saturation discipline holds through the net.
    use convkit::cnn::{zoo, GoldenCnn};
    let net = GoldenCnn::new(zoo::lenet_ish(), BlockKind::Conv2).unwrap();
    let spec = net.spec.clone();
    let q = QFormat::new(spec.layers[0].data_bits).unwrap();
    let (oh, ow) = spec.out_hw();
    let bound = (q.max() * (oh * ow) as i64) >> spec.head_shift;
    forall(
        &Config { cases: 24, ..Default::default() },
        "golden logits bounded",
        |rng| (rng.range_i64(0, 1 << 30), 0i64),
        |_| vec![],
        |&(seed, _)| {
            let mut rng = SplitMix64::new(seed as u64);
            let img: Vec<i64> = (0..spec.in_h * spec.in_w)
                .map(|_| rng.range_i64(q.min(), q.max()))
                .collect();
            let logits = net.infer(&img).map_err(|e| e.to_string())?;
            for &l in &logits {
                if l < 0 || l > bound {
                    return Err(format!("logit {l} outside [0, {bound}]"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allocations_never_exceed_any_platform_budget_column() {
    // The Table 5 invariant, generalized: for ANY precision pair, ANY
    // catalogued platform and ANY utilization cap, both allocators stay
    // within EVERY resource column of the capped budget — the property the
    // fleetplan controller's "does one more replica fit" check inherits.
    use convkit::allocate::{allocate_mix, allocate_single, unit_costs};
    use convkit::coordinator::dse::DseEngine;
    use convkit::coordinator::jobs::JobPool;
    use convkit::models::SelectOptions;
    use convkit::platform::Platform;
    use convkit::synthdata::SweepOptions;

    // One registry for the whole property (fitting is the expensive part).
    let registry = DseEngine {
        sweep: SweepOptions { min_bits: 6, max_bits: 12, ..Default::default() },
        select: SelectOptions::default(),
        pool: JobPool::with_workers(2),
        cache: None,
    }
    .run()
    .unwrap()
    .registry;
    let platforms = Platform::all();

    forall(
        &Config { cases: 48, ..Default::default() },
        "allocations respect every budget column",
        |rng| (rng.range_i64(3, 16), rng.range_i64(3, 16)),
        shrink_pair(3),
        |&(d, c)| {
            let unit = unit_costs(&registry, d as u32, c as u32).map_err(|e| e.to_string())?;
            // Derive a cap from the pair so shrinking keeps it reproducible:
            // spread over {0.2, 0.35, 0.5, 0.65, 0.8}.
            let cap = 0.2 + 0.15 * ((d * 7 + c) % 5) as f64;
            for platform in &platforms {
                let budget = platform.capped_budget(cap);
                let mix = allocate_mix(&unit, platform, cap).map_err(|e| e.to_string())?;
                let usage = mix.usage(&unit);
                if !usage.fits_within(&budget) {
                    return Err(format!(
                        "mix on {} at cap {cap}: {usage} exceeds {budget}",
                        platform.name
                    ));
                }
                for (i, u) in unit.iter().enumerate() {
                    let n = allocate_single(u, platform, cap);
                    let usage = u.scaled(n);
                    if !usage.fits_within(&budget) {
                        return Err(format!(
                            "single[{i}] on {} at cap {cap}: {usage} exceeds {budget}",
                            platform.name
                        ));
                    }
                    // Maximality: one more instance must NOT fit (unless the
                    // block is free, which allocate_single reports as 0).
                    if n > 0 && u.scaled(n + 1).fits_within(&budget) {
                        return Err(format!(
                            "single[{i}] on {} at cap {cap}: {n} is not maximal",
                            platform.name
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chaos_conserves_every_request_per_tier() {
    // The chaos engine's ledger law: across random seeds, batch fractions
    // and every fault class, `offered == completed + rejected + shed` holds
    // globally AND per tier — faults may delay or deny work, but no request
    // is ever lost or double-counted, which is what makes recovery-to-SLO
    // a trustworthy objective.
    use convkit::fleetplan::{Autoscaler, SloPolicy};
    use convkit::simulate::{
        run_chaos, ChaosFault, ChaosPlan, Scenario, ScenarioShape, SimFleet, SimRunOptions,
        SimServiceModel,
    };

    forall(
        &Config { cases: 30, ..Default::default() },
        "chaos conserves offered == completed + rejected + shed",
        |rng| (rng.range_i64(1, 1 << 20), rng.range_i64(0, 1 << 20)),
        shrink_pair(0),
        |&(a, b)| {
            let seed = a as u64;
            let batch_frac = (seed % 100) as f64 / 100.0;
            let fault = match (b as u64) % 5 {
                0 => ChaosFault::KillReplica { at_ms: 25.0, network: "a".to_string() },
                1 => ChaosFault::WedgeReplica {
                    at_ms: 10.0,
                    network: "a".to_string(),
                    ordinal: 0,
                    stall_ms: 20.0,
                },
                2 => ChaosFault::FailDevice { at_ms: 30.0, device: "dev1".to_string() },
                // Rebinding dev0 AWAY from its network leaves `a` dead for
                // the rest of the run — the harshest accounting case.
                3 => ChaosFault::RebindDevice {
                    at_ms: 40.0,
                    device: "dev0".to_string(),
                    network: "b".to_string(),
                    replicas: 2,
                    downtime_ms: 5.0,
                },
                _ => ChaosFault::BurstStorm { at_ms: 20.0, len_ms: 30.0, factor: 3 },
            };
            let mut fleet = SimFleet::new(&[
                SimServiceModel::new("a", 0.5, 8, 2).on_platform("dev0", 0.2),
                SimServiceModel::new("b", 0.5, 8, 2).on_platform("dev1", 0.2),
            ])
            .map_err(|e| e.to_string())?;
            let trace = Scenario::new(
                ScenarioShape::Steady,
                vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
                300.0,
                80.0,
                seed,
            )
            .arrivals();
            let plan = ChaosPlan::new(seed, batch_frac).with_fault(fault);
            let opts = SimRunOptions { control_interval_ms: 5.0, cooldown_ticks: 3 };
            let mut scalers: [Autoscaler; 0] = [];
            let policy = SloPolicy::default();
            let r = run_chaos(&mut fleet, &trace, &mut scalers, &policy, &plan, &opts)
                .map_err(|e| e.to_string())?;
            if !r.conserved {
                return Err(format!("engine reported a conservation break: {}", r.to_json()));
            }
            let tier_sum: u64 = r.offered_tier.iter().sum();
            if r.offered != tier_sum {
                return Err(format!("tier split lost arrivals: {} != {tier_sum}", r.offered));
            }
            if r.offered != r.completed + r.rejected + r.shed {
                return Err(format!(
                    "global ledger broke: {} != {} + {} + {}",
                    r.offered, r.completed, r.rejected, r.shed
                ));
            }
            for t in 0..r.offered_tier.len() {
                let back = r.completed_tier[t] + r.rejected_tier[t] + r.shed_tier[t];
                if r.offered_tier[t] != back {
                    return Err(format!(
                        "tier {t} ledger broke: {} != {back}",
                        r.offered_tier[t]
                    ));
                }
            }
            Ok(())
        },
    );
}
