//! Registry-discipline lint (ROADMAP carried-forward item): dispatch over
//! conv block kinds belongs to the `blocks/` registry — `blocks/conv2act.rs`
//! is the worked example of routing through it instead of matching. Any
//! other layer that `match`es on `BlockKind` variants re-hardcodes knowledge
//! the registry owns and silently falls out of date when a block is added,
//! so this test greps the source tree and fails on the first match pattern
//! found outside `blocks/`. Value uses (`BlockKind::Conv2` as an argument,
//! `== BlockKind::Conv3` comparisons, `BlockKind::ALL`) stay legal.
//!
//! The same discipline covers the telemetry plane's metric names: every
//! `MetricsRegistry::{counter,gauge,histogram}` registration must go
//! through the `obs::names` constant table (or a helper resolving to it,
//! like `Stage::metric_name`), never an inline string literal — ad-hoc
//! names fragment the export namespace and dodge the `names::ALL`
//! exhaustiveness test.

use std::fs;
use std::path::{Path, PathBuf};

/// True when the text directly after a `BlockKind::Variant` path continues,
/// past whitespace, with a match-pattern separator: a match arm (`=>`) or an
/// or-pattern (`|`, but not the logical `||` of a value comparison).
fn is_match_pattern(rest: &str) -> bool {
    let rest = rest.trim_start();
    rest.starts_with("=>") || (rest.starts_with('|') && !rest.starts_with("||"))
}

/// 1-based line numbers of every `BlockKind::<Variant>` used as a match
/// pattern in `src`.
fn scan(src: &str) -> Vec<usize> {
    let needle = "BlockKind::";
    let mut hits = Vec::new();
    let mut start = 0;
    while let Some(pos) = src[start..].find(needle) {
        let at = start + pos;
        let after = at + needle.len();
        let ident_end = src[after..]
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
            .map(|o| after + o)
            .unwrap_or(src.len());
        if is_match_pattern(&src[ident_end..]) {
            hits.push(src[..at].bytes().filter(|&b| b == b'\n').count() + 1);
        }
        start = after;
    }
    hits
}

/// Every `.rs` file under `dir`, skipping any directory named `blocks`.
fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = fs::read_dir(dir).unwrap_or_else(|e| panic!("read {dir:?}: {e}"));
    for entry in entries {
        let p = entry.expect("dir entry").path();
        if p.is_dir() {
            if p.file_name().map(|n| n == "blocks").unwrap_or(false) {
                continue;
            }
            rust_sources(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

#[test]
fn only_the_blocks_registry_matches_on_block_kinds() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
    let mut files = Vec::new();
    rust_sources(&root, &mut files);
    files.sort();
    assert!(
        files.len() > 10,
        "the lint walked only {} files — wrong root?",
        files.len()
    );
    let mut offenders = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f).unwrap_or_else(|e| panic!("read {f:?}: {e}"));
        for line in scan(&src) {
            offenders.push(format!("{}:{line}", f.display()));
        }
    }
    assert!(
        offenders.is_empty(),
        "BlockKind match patterns outside blocks/ — route through the \
         registry (see blocks/conv2act.rs) instead:\n  {}",
        offenders.join("\n  ")
    );
}

/// 1-based line numbers of every metrics-registry registration call whose
/// name is an inline string literal instead of an `obs::names` constant.
fn scan_metric_literals(src: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    for needle in [".counter(\"", ".gauge(\"", ".histogram(\""] {
        let mut start = 0;
        while let Some(pos) = src[start..].find(needle) {
            let at = start + pos;
            hits.push(src[..at].bytes().filter(|&b| b == b'\n').count() + 1);
            start = at + needle.len();
        }
    }
    hits.sort_unstable();
    hits
}

#[test]
fn obs_metric_names_go_through_the_names_constant_table() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
    let mut files = Vec::new();
    rust_sources(&root, &mut files);
    files.sort();
    let mut offenders = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f).unwrap_or_else(|e| panic!("read {f:?}: {e}"));
        for line in scan_metric_literals(&src) {
            offenders.push(format!("{}:{line}", f.display()));
        }
    }
    assert!(
        offenders.is_empty(),
        "metric registered under an inline string literal — add a constant \
         to `obs::names` and register through it:\n  {}",
        offenders.join("\n  ")
    );
}

#[test]
fn the_metric_literal_matcher_flags_inline_names_only() {
    assert_eq!(scan_metric_literals("reg.counter(\"adhoc\").inc();"), vec![1]);
    assert_eq!(scan_metric_literals("r.gauge(\"g\");\nr.histogram(\"h\");"), vec![1, 2]);
    assert!(scan_metric_literals("reg.counter(names::SPANS_RECORDED)").is_empty());
    assert!(scan_metric_literals("reg.histogram(stage.metric_name())").is_empty());
}

#[test]
fn the_matcher_recognizes_patterns_and_ignores_value_uses() {
    // Match arms and or-patterns are flagged…
    assert_eq!(scan("match k { BlockKind::Conv2 => 1, _ => 0 }"), vec![1]);
    assert_eq!(scan("BlockKind::Conv2 | BlockKind::Conv3 => 2,").len(), 2);
    assert_eq!(scan("BlockKind::Conv1\n    => 3,"), vec![1]);
    // …value uses are not.
    assert!(scan("k == BlockKind::Conv2 || other").is_empty());
    assert!(scan("BlockKind::ALL.len()").is_empty());
    assert!(scan("GoldenCnn::new(net, BlockKind::Conv2)?").is_empty());
    assert!(scan("let b = BlockKind::Conv4;").is_empty());
}
