#!/usr/bin/env python3
"""Diff two multi-section BENCH_runtime.json baselines (CI perf trajectory).

The bench harness (`rust/src/util/bench.rs::write_json_sections`) merges every
bench binary into one file shaped
``{"benches": {SECTION: {"results": [{"name", "mean_ns", ...}]}}}``.
This script compares a current baseline against the previously archived one
and emits a per-section markdown table of mean-latency deltas — appended to
the GitHub job summary by the CI bench job so perf PRs carry their own
before/after evidence.

By default the exit code is 0: the diff is evidence, not a gate (noise on
shared CI runners would make a hard threshold flaky), and regressions are
flagged inline. Sections named with ``--fail-on SECTION`` (repeatable) are
the exception — they ARE gated: if any bench present in both baselines
under a gated section is slower by more than ``--fail-pct`` percent
(default 20), the script prints the offending entries and exits 1. The
serving hot-path sections (`runtime_serve`) are gated in CI so a perf PR
cannot silently undo them; a gated section that disappears from the
current baseline also fails.

It can additionally diff the simulator's capacity report (the JSON written
by ``convkit simulate --out``, top-level key ``simulate``): pass
``--simulate CURRENT_SIM.json PREVIOUS_SIM.json`` to append a section with
max-sustainable-QPS and per-network p95 deltas. Capacity reports are
deterministic for a fixed seed/scenario/registry, so a delta here means the
models or the serving semantics actually changed — unlike the timing
tables, it is noise-free evidence.

Likewise for the SLO policy search (``convkit policysearch --out``,
top-level key ``policysearch``): pass ``--policysearch CURRENT PREVIOUS``
to append the Pareto-front movement — front size, best sustained QPS and
best p95 across the front. Byte-deterministic for a fixed seed, same as
the capacity report.

And for the heterogeneous pool plan (``convkit plan --out``, top-level key
``pool``): pass ``--pool CURRENT_POOL.json PREVIOUS_POOL.json`` to append a
per-device table of replica counts, bindings and worst-column utilization,
plus per-network replica totals across the pool. The plan is deterministic
for a fixed registry and pool spec, so any delta is a real planner or
model change — advisory, never gated.

And for the telemetry-plane snapshot (``convkit simulate --obs-out`` /
``convkit obs --out``, top-level key ``obs``): pass
``--obs CURRENT_OBS.json PREVIOUS_OBS.json`` to append span accounting
(recorded/dropped, per-kind counts) and per-stage histogram deltas
(count, mean, p95). The snapshot is emitted by the same deterministic
virtual-clock run as the capacity report, so a moved span count means a
scheduling-semantics change, not noise — advisory, never gated (the
*overhead* of recording is gated separately through the
``obs_span_overhead`` bench section).

And for the model-drift scorecard (``convkit simulate --drift-out`` /
``convkit drift --out``, top-level key ``drift``): pass
``--drift CURRENT_DRIFT.json PREVIOUS_DRIFT.json`` to append per-network,
per-component MPE/MAPE movement, flag transitions, the proposed re-fitted
contention slope and span-ring drop accounting. Emitted by the same
deterministic run as the capacity report, so a moved score means the
models or the engine changed — advisory, never gated (the *overhead* of
tracing is gated separately through the ``obs_trace_overhead`` bench
section).

And for the chaos report (``convkit chaos --out``, top-level key
``chaos``): pass ``--chaos CURRENT_CHAOS.json PREVIOUS_CHAOS.json`` to
append the fault-injection scorecard — conservation, shed/rejected counts
by tier, per-fault recovery-to-SLO deltas and tier fairness. The report is
byte-deterministic for a fixed seed/plan (CI separately runs the command
twice and ``cmp``s the outputs), so any delta is a real scheduling or
control change — advisory, never gated. The *overhead* of the weighted-
fair tier pick is gated through the ``router_wfq_overhead`` bench section,
which carries an extra intra-run bound: ``router_wfq`` must stay within
5% of ``router_least_outstanding`` in the CURRENT baseline, regardless of
the archived one.

Usage: bench_diff.py CURRENT.json PREVIOUS.json [--regress-pct 25]
                     [--fail-on SECTION]... [--fail-pct 20]
                     [--simulate CURRENT_SIM.json PREVIOUS_SIM.json]
                     [--policysearch CURRENT_POL.json PREVIOUS_POL.json]
                     [--pool CURRENT_POOL.json PREVIOUS_POOL.json]
                     [--obs CURRENT_OBS.json PREVIOUS_OBS.json]
                     [--drift CURRENT_DRIFT.json PREVIOUS_DRIFT.json]
                     [--chaos CURRENT_CHAOS.json PREVIOUS_CHAOS.json]
"""

from __future__ import annotations

import argparse
import json
import sys


def load_sections(path: str) -> dict:
    """{section: {bench_name: mean_ns}} (empty on missing/old-format files)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    out = {}
    for section, body in doc.get("benches", {}).items():
        out[section] = {
            r["name"]: float(r["mean_ns"]) for r in body.get("results", [])
        }
    return out


def fmt_ns(ns: float) -> str:
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def diff(current: dict, previous: dict, regress_pct: float) -> str:
    lines = ["## Bench baseline diff (mean per iteration)", ""]
    if not previous:
        lines.append("_No previous baseline artifact — nothing to diff "
                     "(first run on this branch?)._")
        return "\n".join(lines) + "\n"
    regressions = 0
    for section in sorted(set(current) | set(previous)):
        cur = current.get(section, {})
        prev = previous.get(section, {})
        lines.append(f"### `{section}`")
        lines.append("")
        lines.append("| bench | previous | current | delta |")
        lines.append("|---|---:|---:|---:|")
        for name in sorted(set(cur) | set(prev)):
            c, p = cur.get(name), prev.get(name)
            if c is None:
                lines.append(f"| {name} | {fmt_ns(p)} | _removed_ | |")
            elif p is None:
                lines.append(f"| {name} | _new_ | {fmt_ns(c)} | |")
            else:
                pct = 100.0 * (c - p) / p if p > 0 else 0.0
                flag = ""
                if pct >= regress_pct:
                    flag = " ⚠️ regression?"
                    regressions += 1
                elif pct <= -regress_pct:
                    flag = " 🚀"
                lines.append(
                    f"| {name} | {fmt_ns(p)} | {fmt_ns(c)} | {pct:+.1f}%{flag} |"
                )
        lines.append("")
    lines.append(
        f"_{regressions} section entr{'y' if regressions == 1 else 'ies'} "
        f"slower by ≥ {regress_pct:.0f}% (advisory — CI runner noise applies)._"
    )
    return "\n".join(lines) + "\n"


# Intra-run bound for the weighted-fair router section: the WFQ pick must
# stay within this percentage of the plain least-outstanding scan measured
# in the SAME baseline (runner-speed independent, so it can be hard-gated
# even though both absolute timings wobble with the machine).
WFQ_SECTION = "router_wfq_overhead"
WFQ_BASE_BENCH = "router_least_outstanding"
WFQ_BENCH = "router_wfq"
WFQ_OVERHEAD_PCT = 5.0


def gate(current: dict, previous: dict, sections: list, fail_pct: float) -> list:
    """Hard-gate failures: entries in a gated section slower by > fail_pct.

    Returns a list of human-readable failure strings (empty = gate passes).
    With no previous baseline there is nothing to regress against, so the
    gate passes vacuously — but a gated section missing from the *current*
    baseline is a failure (the bench was removed or did not run). Gating
    ``router_wfq_overhead`` additionally enforces the intra-run WFQ bound
    (see ``WFQ_OVERHEAD_PCT``), which needs no previous baseline at all.
    """
    failures = []
    for section in sections:
        cur = current.get(section)
        if cur is None:
            failures.append(
                f"{section}: gated section missing from the current baseline"
            )
            continue
        if section == WFQ_SECTION:
            base = cur.get(WFQ_BASE_BENCH, 0.0)
            wfq = cur.get(WFQ_BENCH, 0.0)
            if base <= 0 or wfq <= 0:
                failures.append(
                    f"{section}: needs both {WFQ_BASE_BENCH} and {WFQ_BENCH} "
                    "in the current baseline"
                )
            else:
                pct = 100.0 * (wfq - base) / base
                if pct > WFQ_OVERHEAD_PCT:
                    failures.append(
                        f"{section}: {WFQ_BENCH} {fmt_ns(wfq)} is "
                        f"{pct:+.1f}% over {WFQ_BASE_BENCH} {fmt_ns(base)} "
                        f"(intra-run limit +{WFQ_OVERHEAD_PCT:.0f}%)"
                    )
        if not previous:
            continue
        prev = previous.get(section, {})
        for name in sorted(set(cur) & set(prev)):
            c, p = cur[name], prev[name]
            if p <= 0:
                continue
            pct = 100.0 * (c - p) / p
            if pct > fail_pct:
                failures.append(
                    f"{section}/{name}: {fmt_ns(p)} -> {fmt_ns(c)} "
                    f"({pct:+.1f}%, limit +{fail_pct:.0f}%)"
                )
    return failures


def load_simulate(path: str) -> dict:
    """The `simulate` object of a capacity report (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("simulate", {})


def fmt_delta(cur: float, prev: float) -> str:
    if prev == 0:
        return "n/a" if cur == 0 else "new"
    return f"{100.0 * (cur - prev) / prev:+.1f}%"


def diff_simulate(current: dict, previous: dict) -> str:
    lines = ["## Simulated capacity diff (`convkit simulate`)", ""]
    if not current:
        lines.append("_No current capacity report._")
        return "\n".join(lines) + "\n"
    if not previous:
        lines.append("_No previous capacity report artifact — nothing to diff._")
        return "\n".join(lines) + "\n"
    lines.append(
        f"Scenario `{current.get('scenario', '?')}` seed {current.get('seed', '?')} "
        f"on {current.get('platform', '?')}: "
        f"{current.get('events', 0)} virtual events."
    )
    lines.append("")
    lines.append("| metric | previous | current | delta |")
    lines.append("|---|---:|---:|---:|")
    cq = float(current.get("max_sustainable_qps", 0.0))
    pq = float(previous.get("max_sustainable_qps", 0.0))
    lines.append(
        f"| max sustainable QPS | {pq:.1f} | {cq:.1f} | {fmt_delta(cq, pq)} |"
    )
    prev_nets = {n["network"]: n for n in previous.get("networks", [])}
    cur_names = set()
    for n in current.get("networks", []):
        name = n["network"]
        cur_names.add(name)
        p = prev_nets.get(name)
        c95 = float(n.get("p95_ms", 0.0))
        if p is None:
            cov = float(n.get("overload_rate", 0.0))
            lines.append(f"| {name} p95 (ms) | _new_ | {c95:.4f} | |")
            lines.append(f"| {name} overload | _new_ | {100 * cov:.2f}% | |")
            continue
        p95 = float(p.get("p95_ms", 0.0))
        lines.append(
            f"| {name} p95 (ms) | {p95:.4f} | {c95:.4f} | {fmt_delta(c95, p95)} |"
        )
        cov = float(n.get("overload_rate", 0.0))
        pov = float(p.get("overload_rate", 0.0))
        lines.append(
            f"| {name} overload | {100 * pov:.2f}% | {100 * cov:.2f}% "
            f"| {fmt_delta(cov, pov)} |"
        )
    for name in sorted(set(prev_nets) - cur_names):
        p95 = float(prev_nets[name].get("p95_ms", 0.0))
        lines.append(f"| {name} p95 (ms) | {p95:.4f} | _removed_ | |")
    lines.append("")
    return "\n".join(lines) + "\n"


def load_policysearch(path: str) -> dict:
    """The `policysearch` object of a Pareto report (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("policysearch", {})


def front_rows(doc: dict) -> list:
    return [r for r in doc.get("rows", []) if r.get("pareto")]


def diff_policysearch(current: dict, previous: dict) -> str:
    lines = ["## SLO policy-search diff (`convkit policysearch`)", ""]
    if not current:
        lines.append("_No current policy-search report._")
        return "\n".join(lines) + "\n"
    cur_front = front_rows(current)
    lines.append(
        f"Scenario `{current.get('scenario', '?')}` seed {current.get('seed', '?')} "
        f"on {current.get('platform', '?')}: grid of {current.get('grid', 0)} "
        f"policies over {current.get('arrivals', 0)} arrivals, "
        f"Pareto front of {len(cur_front)}."
    )
    lines.append("")
    if not previous:
        lines.append("_No previous policy-search artifact — nothing to diff._")
        return "\n".join(lines) + "\n"
    prev_front = front_rows(previous)

    def best(rows: list, key: str, biggest: bool) -> float:
        vals = [float(r.get(key, 0.0)) for r in rows]
        if not vals:
            return 0.0
        return max(vals) if biggest else min(vals)

    lines.append("| metric | previous | current | delta |")
    lines.append("|---|---:|---:|---:|")
    lines.append(
        f"| Pareto front size | {len(prev_front)} | {len(cur_front)} "
        f"| {len(cur_front) - len(prev_front):+d} |"
    )
    for key, biggest, fmt in [
        ("sustained_qps", True, "{:.1f}"),
        ("p95_ms", False, "{:.4f}"),
        ("replica_seconds", False, "{:.3f}"),
    ]:
        c = best(cur_front, key, biggest)
        p = best(prev_front, key, biggest)
        word = "best" if biggest else "min"
        lines.append(
            f"| front {word} {key} | {fmt.format(p)} | {fmt.format(c)} "
            f"| {fmt_delta(c, p)} |"
        )
    lines.append("")
    return "\n".join(lines) + "\n"


def load_pool(path: str) -> dict:
    """The `pool` object of a pool plan (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("pool", {})


def worst_util(device: dict) -> float:
    return max([float(v) for v in device.get("utilization", {}).values()] or [0.0])


def network_totals(pool: dict) -> dict:
    """{network: replicas summed across every device of the pool}."""
    totals: dict = {}
    for d in pool.get("devices", []):
        for n in d.get("networks", []):
            totals[n["network"]] = totals.get(n["network"], 0) + int(n["replicas"])
    return totals


def diff_pool(current: dict, previous: dict) -> str:
    lines = ["## Heterogeneous pool-plan diff (`convkit plan`)", ""]
    if not current:
        lines.append("_No current pool plan._")
        return "\n".join(lines) + "\n"
    devices = current.get("devices", [])
    used = sum(1 for d in devices if d.get("networks"))
    lines.append(
        f"{len(devices)} device(s), {used} used, "
        f"{current.get('total_replicas', 0)} replica(s) in total."
    )
    lines.append("")
    if not previous:
        lines.append("_No previous pool-plan artifact — nothing to diff._")
        return "\n".join(lines) + "\n"
    prev_devs = {d["device"]: d for d in previous.get("devices", [])}
    cur_names = set()
    lines.append("| device | previous | current | binding |")
    lines.append("|---|---:|---:|---|")
    for d in devices:
        name = d["device"]
        cur_names.add(name)
        cur_cell = f"{d.get('total_replicas', 0)} repl, {worst_util(d):.1f}%"
        binding = d.get("binding") or "—"
        p = prev_devs.get(name)
        if p is None:
            lines.append(f"| {name} | _new_ | {cur_cell} | {binding} |")
            continue
        prev_cell = f"{p.get('total_replicas', 0)} repl, {worst_util(p):.1f}%"
        prev_binding = p.get("binding") or "—"
        if prev_binding != binding:
            binding = f"{prev_binding} → {binding}"
        lines.append(f"| {name} | {prev_cell} | {cur_cell} | {binding} |")
    for name in sorted(set(prev_devs) - cur_names):
        p = prev_devs[name]
        lines.append(
            f"| {name} | {p.get('total_replicas', 0)} repl, "
            f"{worst_util(p):.1f}% | _removed_ | |"
        )
    lines.append("")
    cur_nets = network_totals(current)
    prev_nets = network_totals(previous)
    lines.append("| network | previous replicas | current | delta |")
    lines.append("|---|---:|---:|---:|")
    for name in sorted(set(cur_nets) | set(prev_nets)):
        c, p = cur_nets.get(name), prev_nets.get(name)
        if c is None:
            lines.append(f"| {name} | {p} | _removed_ | |")
        elif p is None:
            lines.append(f"| {name} | _new_ | {c} | |")
        else:
            lines.append(f"| {name} | {p} | {c} | {c - p:+d} |")
    lines.append("")
    return "\n".join(lines) + "\n"


def load_obs(path: str) -> dict:
    """The `obs` object of a telemetry snapshot (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("obs", {})


def diff_obs(current: dict, previous: dict) -> str:
    lines = ["## Telemetry-plane diff (`convkit simulate --obs-out`)", ""]
    if not current:
        lines.append("_No current observability snapshot._")
        return "\n".join(lines) + "\n"
    spans = current.get("spans", {})
    journal = current.get("journal", {})
    lines.append(
        f"{spans.get('obs_spans_recorded', 0)} span(s) recorded, "
        f"{spans.get('obs_spans_dropped', 0)} dropped, "
        f"{journal.get('total_recorded', 0)} journal event(s)."
    )
    lines.append("")
    if not previous:
        lines.append("_No previous observability snapshot — nothing to diff._")
        return "\n".join(lines) + "\n"
    prev_spans = previous.get("spans", {})
    prev_journal = previous.get("journal", {})
    lines.append("| metric | previous | current | delta |")
    lines.append("|---|---:|---:|---:|")
    scalars = [
        ("spans recorded", prev_spans.get("obs_spans_recorded", 0),
         spans.get("obs_spans_recorded", 0)),
        ("spans dropped", prev_spans.get("obs_spans_dropped", 0),
         spans.get("obs_spans_dropped", 0)),
        ("journal events", prev_journal.get("total_recorded", 0),
         journal.get("total_recorded", 0)),
    ]
    cur_kinds = spans.get("kinds", {})
    prev_kinds = prev_spans.get("kinds", {})
    for kind in sorted(set(cur_kinds) | set(prev_kinds)):
        scalars.append(
            (f"span kind `{kind}`", prev_kinds.get(kind, 0),
             cur_kinds.get(kind, 0))
        )
    for label, p, c in scalars:
        lines.append(
            f"| {label} | {p} | {c} | {fmt_delta(float(c), float(p))} |"
        )
    lines.append("")
    cur_hists = {h["name"]: h for h in current.get("histograms", [])}
    prev_hists = {h["name"]: h for h in previous.get("histograms", [])}
    lines.append("| stage histogram | previous mean/p95 | current mean/p95 "
                 "| mean delta |")
    lines.append("|---|---:|---:|---:|")
    for name in sorted(set(cur_hists) | set(prev_hists)):
        c, p = cur_hists.get(name), prev_hists.get(name)
        if c is None:
            lines.append(f"| {name} | {fmt_ns(float(p['mean_ns']))} / "
                         f"{fmt_ns(float(p['p95_ns']))} | _removed_ | |")
            continue
        cur_cell = (f"{fmt_ns(float(c['mean_ns']))} / "
                    f"{fmt_ns(float(c['p95_ns']))} (n={c.get('count', 0)})")
        if p is None:
            lines.append(f"| {name} | _new_ | {cur_cell} | |")
            continue
        prev_cell = (f"{fmt_ns(float(p['mean_ns']))} / "
                     f"{fmt_ns(float(p['p95_ns']))} (n={p.get('count', 0)})")
        delta = fmt_delta(float(c["mean_ns"]), float(p["mean_ns"]))
        lines.append(f"| {name} | {prev_cell} | {cur_cell} | {delta} |")
    lines.append("")
    return "\n".join(lines) + "\n"


def load_drift(path: str) -> dict:
    """The `drift` object of a drift report (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("drift", {})


def drift_scores(doc: dict) -> dict:
    """{(network, model): score-row} across the report."""
    out = {}
    for n in doc.get("networks", []):
        for m in n.get("models", []):
            out[(n["network"], m["model"])] = m
    return out


def fmt_alpha(v) -> str:
    return "—" if v is None else f"{float(v):.3f}"


def diff_drift(current: dict, previous: dict) -> str:
    lines = ["## Model-drift diff (`convkit simulate --drift-out`)", ""]
    if not current:
        lines.append("_No current drift report._")
        return "\n".join(lines) + "\n"
    cur_scores = drift_scores(current)
    flagged = [k for k, m in cur_scores.items() if m.get("flagged")]
    lines.append(
        f"{len(current.get('networks', []))} network(s) scored, "
        f"{len(flagged)} flagged component(s), "
        f"{current.get('spans_dropped', 0)} span(s) dropped, "
        f"proposed α {fmt_alpha(current.get('proposed_alpha'))}."
    )
    lines.append("")
    if not previous:
        lines.append("_No previous drift-report artifact — nothing to diff._")
        return "\n".join(lines) + "\n"
    prev_scores = drift_scores(previous)
    lines.append("| network / model | previous MAPE | current MAPE "
                 "| samples | flag |")
    lines.append("|---|---:|---:|---:|---|")
    for key in sorted(set(cur_scores) | set(prev_scores)):
        network, model = key
        c, p = cur_scores.get(key), prev_scores.get(key)
        if c is None:
            lines.append(
                f"| {network} / {model} | {100 * float(p['mape']):.2f}% "
                f"| _removed_ | | |"
            )
            continue
        cur_mape = f"{100 * float(c['mape']):.2f}%"
        flag_now = "DRIFTED" if c.get("flagged") else "ok"
        if p is None:
            lines.append(
                f"| {network} / {model} | _new_ | {cur_mape} "
                f"| {c.get('samples', 0)} | {flag_now} |"
            )
            continue
        flag_prev = "DRIFTED" if p.get("flagged") else "ok"
        flag = flag_now if flag_prev == flag_now else f"{flag_prev} → {flag_now}"
        lines.append(
            f"| {network} / {model} | {100 * float(p['mape']):.2f}% "
            f"| {cur_mape} | {c.get('samples', 0)} | {flag} |"
        )
    pa_c, pa_p = current.get("proposed_alpha"), previous.get("proposed_alpha")
    if pa_c != pa_p:
        lines.append(
            f"| proposed α | {fmt_alpha(pa_p)} | {fmt_alpha(pa_c)} | | |"
        )
    lines.append("")
    return "\n".join(lines) + "\n"


def load_chaos(path: str) -> dict:
    """The `chaos` object of a chaos report (empty when unreadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    return doc.get("chaos", {})


def tier_cell(doc: dict, key: str) -> str:
    """Render a `[interactive, batch]` tier counter pair."""
    pair = doc.get(key, [0, 0])
    if not isinstance(pair, list) or len(pair) != 2:
        return "?"
    return f"{pair[0]} / {pair[1]}"


def diff_chaos(current: dict, previous: dict) -> str:
    lines = ["## Chaos-run diff (`convkit chaos`)", ""]
    if not current:
        lines.append("_No current chaos report._")
        return "\n".join(lines) + "\n"
    faults = current.get("faults", [])
    recovered = sum(1 for f in faults if f.get("recovered"))
    conserved = "conserved" if current.get("conserved") else "**LEAKED REQUESTS**"
    lines.append(
        f"Seed {current.get('seed', '?')}, batch fraction "
        f"{current.get('batch_frac', 0)}: {current.get('offered', 0)} offered "
        f"over {current.get('virtual_ms', 0)} virtual ms, {conserved}; "
        f"{recovered}/{len(faults)} fault(s) recovered."
    )
    lines.append("")
    if not previous:
        lines.append("_No previous chaos-report artifact — nothing to diff._")
        return "\n".join(lines) + "\n"
    lines.append("| metric | previous | current | delta |")
    lines.append("|---|---:|---:|---:|")
    for key in ["offered", "admitted", "rejected", "shed", "completed"]:
        c = float(current.get(key, 0))
        p = float(previous.get(key, 0))
        lines.append(f"| {key} | {p:.0f} | {c:.0f} | {fmt_delta(c, p)} |")
    for key in ["rejected_tier", "shed_tier", "completed_tier"]:
        lines.append(
            f"| {key} (int / batch) | {tier_cell(previous, key)} "
            f"| {tier_cell(current, key)} | |"
        )
    for key, fmt in [("worst_recovery_ms", "{:.3f}"), ("tier_fairness", "{:.4f}")]:
        c = float(current.get(key, 0.0))
        p = float(previous.get(key, 0.0))
        lines.append(
            f"| {key} | {fmt.format(p)} | {fmt.format(c)} | {fmt_delta(c, p)} |"
        )
    lines.append("")
    prev_faults = {f.get("label", f.get("kind", "?")): f
                   for f in previous.get("faults", [])}
    cur_names = set()
    lines.append("| fault | previous recovery | current recovery | recovered |")
    lines.append("|---|---:|---:|---|")
    for f in faults:
        name = f.get("label", f.get("kind", "?"))
        cur_names.add(name)
        c_ms = float(f.get("recovery_ms", 0.0))
        c_ok = "yes" if f.get("recovered") else "NO"
        p = prev_faults.get(name)
        if p is None:
            lines.append(f"| {name} | _new_ | {c_ms:.3f} ms | {c_ok} |")
            continue
        p_ms = float(p.get("recovery_ms", 0.0))
        p_ok = "yes" if p.get("recovered") else "NO"
        ok = c_ok if p_ok == c_ok else f"{p_ok} → {c_ok}"
        lines.append(f"| {name} | {p_ms:.3f} ms | {c_ms:.3f} ms | {ok} |")
    for name in sorted(set(prev_faults) - cur_names):
        p = prev_faults[name]
        lines.append(
            f"| {name} | {float(p.get('recovery_ms', 0.0)):.3f} ms "
            f"| _removed_ | |"
        )
    lines.append("")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("previous")
    ap.add_argument("--regress-pct", type=float, default=25.0,
                    help="flag entries slower by at least this percentage")
    ap.add_argument("--fail-on", action="append", default=[], metavar="SECTION",
                    help="hard-gate this baseline section (repeatable): exit 1 "
                         "if any of its benches regress by more than --fail-pct")
    ap.add_argument("--fail-pct", type=float, default=20.0,
                    help="regression threshold for --fail-on sections")
    ap.add_argument("--simulate", nargs=2, metavar=("CUR_SIM", "PREV_SIM"),
                    help="also diff two `convkit simulate --out` reports")
    ap.add_argument("--policysearch", nargs=2, metavar=("CUR_POL", "PREV_POL"),
                    help="also diff two `convkit policysearch --out` reports")
    ap.add_argument("--pool", nargs=2, metavar=("CUR_POOL", "PREV_POOL"),
                    help="also diff two `convkit plan --out` pool plans")
    ap.add_argument("--obs", nargs=2, metavar=("CUR_OBS", "PREV_OBS"),
                    help="also diff two `convkit simulate --obs-out` "
                         "telemetry snapshots")
    ap.add_argument("--drift", nargs=2, metavar=("CUR_DRIFT", "PREV_DRIFT"),
                    help="also diff two `convkit simulate --drift-out` "
                         "model-drift reports")
    ap.add_argument("--chaos", nargs=2, metavar=("CUR_CHAOS", "PREV_CHAOS"),
                    help="also diff two `convkit chaos --out` fault-injection "
                         "reports")
    args = ap.parse_args()
    current = load_sections(args.current)
    previous = load_sections(args.previous)
    print(diff(current, previous, args.regress_pct))
    if args.simulate:
        cur_sim, prev_sim = args.simulate
        print(diff_simulate(load_simulate(cur_sim), load_simulate(prev_sim)))
    if args.policysearch:
        cur_pol, prev_pol = args.policysearch
        print(diff_policysearch(
            load_policysearch(cur_pol), load_policysearch(prev_pol)
        ))
    if args.pool:
        cur_pool, prev_pool = args.pool
        print(diff_pool(load_pool(cur_pool), load_pool(prev_pool)))
    if args.obs:
        cur_obs, prev_obs = args.obs
        print(diff_obs(load_obs(cur_obs), load_obs(prev_obs)))
    if args.drift:
        cur_drift, prev_drift = args.drift
        print(diff_drift(load_drift(cur_drift), load_drift(prev_drift)))
    if args.chaos:
        cur_chaos, prev_chaos = args.chaos
        print(diff_chaos(load_chaos(cur_chaos), load_chaos(prev_chaos)))
    if args.fail_on:
        failures = gate(current, previous, args.fail_on, args.fail_pct)
        if failures:
            print(f"## PERF GATE FAILED (> +{args.fail_pct:.0f}% on a gated "
                  "section)")
            print()
            for f in failures:
                print(f"- {f}")
            return 1
        gated = ", ".join(f"`{s}`" for s in args.fail_on)
        print(f"_Perf gate OK: {gated} within +{args.fail_pct:.0f}%._")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
