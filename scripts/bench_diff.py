#!/usr/bin/env python3
"""Diff two multi-section BENCH_runtime.json baselines (CI perf trajectory).

The bench harness (`rust/src/util/bench.rs::write_json_sections`) merges every
bench binary into one file shaped
``{"benches": {SECTION: {"results": [{"name", "mean_ns", ...}]}}}``.
This script compares a current baseline against the previously archived one
and emits a per-section markdown table of mean-latency deltas — appended to
the GitHub job summary by the CI bench job so perf PRs carry their own
before/after evidence.

Exit code is always 0: the diff is evidence, not a gate (noise on shared CI
runners would make a hard threshold flaky). Regressions are flagged inline.

Usage: bench_diff.py CURRENT.json PREVIOUS.json [--regress-pct 25]
"""

from __future__ import annotations

import argparse
import json
import sys


def load_sections(path: str) -> dict:
    """{section: {bench_name: mean_ns}} (empty on missing/old-format files)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: could not read {path}: {e}", file=sys.stderr)
        return {}
    out = {}
    for section, body in doc.get("benches", {}).items():
        out[section] = {
            r["name"]: float(r["mean_ns"]) for r in body.get("results", [])
        }
    return out


def fmt_ns(ns: float) -> str:
    if ns >= 1e9:
        return f"{ns / 1e9:.2f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} µs"
    return f"{ns:.0f} ns"


def diff(current: dict, previous: dict, regress_pct: float) -> str:
    lines = ["## Bench baseline diff (mean per iteration)", ""]
    if not previous:
        lines.append("_No previous baseline artifact — nothing to diff "
                     "(first run on this branch?)._")
        return "\n".join(lines) + "\n"
    regressions = 0
    for section in sorted(set(current) | set(previous)):
        cur = current.get(section, {})
        prev = previous.get(section, {})
        lines.append(f"### `{section}`")
        lines.append("")
        lines.append("| bench | previous | current | delta |")
        lines.append("|---|---:|---:|---:|")
        for name in sorted(set(cur) | set(prev)):
            c, p = cur.get(name), prev.get(name)
            if c is None:
                lines.append(f"| {name} | {fmt_ns(p)} | _removed_ | |")
            elif p is None:
                lines.append(f"| {name} | _new_ | {fmt_ns(c)} | |")
            else:
                pct = 100.0 * (c - p) / p if p > 0 else 0.0
                flag = ""
                if pct >= regress_pct:
                    flag = " ⚠️ regression?"
                    regressions += 1
                elif pct <= -regress_pct:
                    flag = " 🚀"
                lines.append(
                    f"| {name} | {fmt_ns(p)} | {fmt_ns(c)} | {pct:+.1f}%{flag} |"
                )
        lines.append("")
    lines.append(
        f"_{regressions} section entr{'y' if regressions == 1 else 'ies'} "
        f"slower by ≥ {regress_pct:.0f}% (advisory — CI runner noise applies)._"
    )
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("previous")
    ap.add_argument("--regress-pct", type=float, default=25.0,
                    help="flag entries slower by at least this percentage")
    args = ap.parse_args()
    report = diff(
        load_sections(args.current), load_sections(args.previous), args.regress_pct
    )
    print(report)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
