#!/usr/bin/env python3
"""Calibrate the simulator's device-contention slope from a measured
shared-bandwidth microbenchmark.

The virtual-clock engine (rust/src/simulate/engine.rs) stretches a batch's
service time by ``1 + alpha * x`` where ``x`` is the co-located utilization
share excluding the replica itself. This script measures that slope on real
silicon instead of guessing it:

1. Run one memory-streaming worker (a numpy triad over an array far larger
   than the last-level cache) alone and record its per-pass time — the
   uncontended service rate.
2. Re-run with K co-located workers (K = 2, 4, ...), all streaming
   simultaneously; record each worker's per-pass time and the aggregate
   pass rate.
3. Estimate one worker's utilization share of the shared device as
   u = solo bandwidth / peak aggregate bandwidth (u = 1 when a single
   worker already saturates the device, as on a 1-core host; u ~ 1/cores
   on a machine whose memory system scales to the core count). A K-worker
   run then samples the contention curve at co-located-share
   x = (K - 1) * u with measured slowdown s = t_K / t_1.
4. Fit alpha by least squares through the origin on (x, slowdown - 1):
   alpha = sum((s-1) * x) / sum(x^2), over the points with x <= 1 — the
   simulator packs devices to at most their capped budget, so samples from
   an oversubscribed device (x > 1) would extrapolate interference the
   model never evaluates. The same estimator is implemented in
   rust/src/simulate/calibrate.rs (`fit_alpha`) for fleets that want to
   re-calibrate against their own hosts; this script is the reference
   harness the shipped DEFAULT_CONTENTION_ALPHA was produced with.

Usage:
    python3 scripts/calibrate_alpha.py [--mib 64] [--passes 8] [--trials 3]

Prints a JSON report: the per-K samples, the (x, slowdown) points and the
fitted alpha. Pure stdlib + numpy; no GPU, no Rust toolchain needed.
"""

import argparse
import json
import multiprocessing as mp
import os
import sys
import time


def _stream_worker(mib, passes, start_evt, out_q):
    """One co-located replica: stream `mib` MiB through memory `passes`
    times and report the best per-pass wall time (seconds)."""
    import numpy as np

    n = mib * 1024 * 1024 // 8
    a = np.ones(n)
    b = np.full(n, 2.0)
    c = np.empty(n)
    # Touch everything once so faults don't pollute the timed region.
    c[:] = a + b
    start_evt.wait()
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        # STREAM-style triad: bandwidth-bound at this footprint.
        np.multiply(b, 0.5, out=c)
        np.add(c, a, out=c)
        best = min(best, time.perf_counter() - t0)
    out_q.put(best)


def measure(k, mib, passes, trials):
    """Best mean per-worker pass time (s) across `trials` of K co-located
    streaming workers."""
    best = float("inf")
    for _ in range(trials):
        start_evt = mp.Event()
        out_q = mp.Queue()
        procs = [
            mp.Process(target=_stream_worker, args=(mib, passes, start_evt, out_q))
            for _ in range(k)
        ]
        for p in procs:
            p.start()
        # Let every worker finish warm-up before releasing the herd.
        time.sleep(0.3)
        start_evt.set()
        times = [out_q.get() for _ in procs]
        for p in procs:
            p.join()
        best = min(best, sum(times) / len(times))
    return best


def fit_alpha(points):
    """Least squares through the origin for slowdown = 1 + alpha * x,
    i.e. alpha = sum((s - 1) * x) / sum(x^2). Mirrors
    rust/src/simulate/calibrate.rs::fit_alpha."""
    num = sum((s - 1.0) * x for x, s in points)
    den = sum(x * x for x, _ in points)
    return num / den if den > 0 else 0.0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mib", type=int, default=64, help="per-worker footprint (MiB)")
    ap.add_argument("--passes", type=int, default=8, help="timed passes per worker")
    ap.add_argument("--trials", type=int, default=3, help="trials per K (best kept)")
    args = ap.parse_args()

    cpus = os.cpu_count() or 2
    # Sample solo, pairwise and a packed co-location; always go past the
    # core count so the device is genuinely shared at the top end.
    ks = sorted({1, 2, 4, min(2 * cpus, 8), cpus})

    samples = []
    for k in ks:
        t = measure(k, args.mib, args.passes, args.trials)
        # Aggregate pass rate in passes/s: K workers each finishing a pass
        # every t seconds move K/t worker-passes of data per second.
        samples.append({"workers": k, "pass_s": t, "aggregate_rate": k / t})
        print(f"# K={k}: {t * 1e3:.3f} ms/pass", file=sys.stderr)

    solo = samples[0]["pass_s"]
    peak_rate = max(s["aggregate_rate"] for s in samples)
    # One worker's share of the shared device: how much of the peak
    # aggregate bandwidth it consumes running alone.
    u = (1.0 / solo) / peak_rate
    points = []
    for s in samples:
        if s["workers"] == 1:
            continue
        x = (s["workers"] - 1) * u
        slowdown = s["pass_s"] / solo
        points.append((x, slowdown))

    fit_points = [(x, s) for x, s in points if x <= 1.0]
    alpha = fit_alpha(fit_points)
    report = {
        "cpus": cpus,
        "footprint_mib": args.mib,
        "solo_share_u": u,
        "samples": samples,
        "points": [{"share_x": x, "slowdown": s} for x, s in points],
        "fit_points": len(fit_points),
        "alpha": alpha,
    }
    json.dump(report, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
